//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes training/eval steps from the Rust
//! request path — Python is never involved at run time.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Compilation happens once per model;
//! every simulated device then reuses the same executable.
//!
//! Calling convention (fixed by `model.flat_train_step`):
//! * train: inputs `params[0..P), x, y` → tuple `(new_params[0..P), loss)`
//! * eval:  inputs `params[0..P), x, y` → tuple `(loss,)`

pub mod manifest;
pub mod pool;

pub use manifest::{Dtype, Manifest, ModelSpec};

use std::path::Path;

use crate::error::{FedError, Result};

/// Model parameters as flat host vectors (one per parameter tensor).
///
/// Kept on the host because FedAvg aggregation is a host-side weighted sum;
/// conversion to PJRT literals happens at step boundaries.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    tensors: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Split a flat dump according to the manifest shapes.
    pub fn from_flat(spec: &ModelSpec, flat: &[f32]) -> Result<ParamSet> {
        if flat.len() != spec.param_count {
            return Err(FedError::Artifact(format!(
                "flat params len {} != param_count {}",
                flat.len(),
                spec.param_count
            )));
        }
        let mut tensors = Vec::with_capacity(spec.param_shapes.len());
        let mut off = 0;
        for shape in &spec.param_shapes {
            let len: usize = shape.iter().product();
            tensors.push(flat[off..off + len].to_vec());
            off += len;
        }
        Ok(ParamSet { tensors })
    }

    /// Zero-initialized parameter set with the manifest's shapes.
    pub fn zeros(spec: &ModelSpec) -> ParamSet {
        ParamSet {
            tensors: spec
                .param_shapes
                .iter()
                .map(|s| vec![0.0; s.iter().product()])
                .collect(),
        }
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True if no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Tensor accessor.
    pub fn tensor(&self, i: usize) -> &[f32] {
        &self.tensors[i]
    }

    /// All tensors.
    pub fn tensors(&self) -> &[Vec<f32>] {
        &self.tensors
    }

    /// Total scalar count.
    pub fn scalar_count(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// `self += other * w` (for FedAvg accumulation).
    pub fn add_scaled(&mut self, other: &ParamSet, w: f32) -> Result<()> {
        if self.tensors.len() != other.tensors.len() {
            return Err(FedError::Fl("param tensor count mismatch".into()));
        }
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            if a.len() != b.len() {
                return Err(FedError::Fl("param tensor shape mismatch".into()));
            }
            for (x, y) in a.iter_mut().zip(b) {
                *x += w * y;
            }
        }
        Ok(())
    }

    /// Multiply every scalar by `w`.
    pub fn scale(&mut self, w: f32) {
        for t in self.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x *= w;
            }
        }
    }

    /// L2 norm over all scalars (divergence diagnostics).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// A compiled model: PJRT executables plus the manifest entry.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    spec: ModelSpec,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    initial: ParamSet,
}

impl ModelRuntime {
    /// Load and compile a model from an artifacts directory.
    pub fn load(artifacts_dir: &Path, model: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let spec = manifest.model(model)?.clone();
        let flat = manifest.load_params(&spec)?;
        let initial = ParamSet::from_flat(&spec, &flat)?;
        let client = xla::PjRtClient::cpu()?;
        let train_exe = compile_hlo(&client, &spec.train_hlo)?;
        let eval_exe = compile_hlo(&client, &spec.eval_hlo)?;
        Ok(ModelRuntime { client, spec, train_exe, eval_exe, initial })
    }

    /// Manifest entry.
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Underlying PJRT client (for diagnostics).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Initial parameters from the artifact dump.
    pub fn initial_params(&self) -> ParamSet {
        self.initial.clone()
    }

    fn param_literals(&self, params: &ParamSet) -> Result<Vec<xla::Literal>> {
        if params.len() != self.spec.n_param_tensors {
            return Err(FedError::Fl(format!(
                "expected {} param tensors, got {}",
                self.spec.n_param_tensors,
                params.len()
            )));
        }
        params
            .tensors()
            .iter()
            .zip(&self.spec.param_shapes)
            .map(|(t, shape)| literal_f32(t, shape))
            .collect()
    }

    /// Build the input literal for a batch of features (f32 models).
    pub fn input_literal_f32(&self, x: &[f32]) -> Result<xla::Literal> {
        if self.spec.input_dtype != Dtype::F32 {
            return Err(FedError::Fl("model expects s32 inputs".into()));
        }
        literal_f32(x, &self.spec.input_shape)
    }

    /// Build the input literal for token models.
    pub fn input_literal_i32(&self, x: &[i32]) -> Result<xla::Literal> {
        if self.spec.input_dtype != Dtype::S32 {
            return Err(FedError::Fl("model expects f32 inputs".into()));
        }
        literal_i32(x, &self.spec.input_shape)
    }

    /// Build the label literal.
    pub fn label_literal(&self, y: &[i32]) -> Result<xla::Literal> {
        literal_i32(y, &self.spec.label_shape)
    }

    /// Run one training step: `params, x, y → (new_params, loss)`.
    pub fn train_step(
        &self,
        params: &ParamSet,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<(ParamSet, f32)> {
        let mut args = self.param_literals(params)?;
        args.push(clone_literal(x)?);
        args.push(clone_literal(y)?);
        let result =
            self.train_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut outs = result.to_tuple()?;
        if outs.len() != self.spec.n_param_tensors + 1 {
            return Err(FedError::Runtime(format!(
                "train step returned {} outputs, expected {}",
                outs.len(),
                self.spec.n_param_tensors + 1
            )));
        }
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let tensors = outs
            .iter()
            .map(|l| l.to_vec::<f32>())
            .collect::<std::result::Result<Vec<_>, _>>()?;
        Ok((ParamSet { tensors }, loss))
    }

    /// Evaluate the loss of `params` on a batch without updating.
    pub fn eval_step(
        &self,
        params: &ParamSet,
        x: &xla::Literal,
        y: &xla::Literal,
    ) -> Result<f32> {
        let mut args = self.param_literals(params)?;
        args.push(clone_literal(x)?);
        args.push(clone_literal(y)?);
        let result =
            self.eval_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.get_first_element::<f32>()?)
    }
}

/// The `xla` crate's `Literal` has no public `Clone`; a same-shape reshape
/// performs the copy.
fn clone_literal(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l.array_shape()?;
    let dims: Vec<i64> = shape.dims().to_vec();
    Ok(l.reshape(&dims)?)
}

fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| FedError::Artifact(format!("loading HLO {}: {e:?}", path.display())))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        return Err(FedError::Fl(format!(
            "data len {} != shape {:?} ({expected})",
            data.len(),
            shape
        )));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        return Err(FedError::Fl(format!(
            "data len {} != shape {:?} ({expected})",
            data.len(),
            shape
        )));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> ModelSpec {
        ModelSpec {
            name: "toy".into(),
            family: "mlp".into(),
            train_hlo: "/tmp/x".into(),
            eval_hlo: "/tmp/y".into(),
            params_file: "/tmp/z".into(),
            param_shapes: vec![vec![2, 3], vec![3]],
            param_count: 9,
            n_param_tensors: 2,
            batch: 4,
            lr: 0.1,
            input_shape: vec![4, 2],
            input_dtype: Dtype::F32,
            label_shape: vec![4],
            label_dtype: Dtype::S32,
            num_classes: 2,
        }
    }

    #[test]
    fn paramset_split_and_accessors() {
        let spec = toy_spec();
        let flat: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let p = ParamSet::from_flat(&spec, &flat).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.tensor(0), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(p.tensor(1), &[6., 7., 8.]);
        assert_eq!(p.scalar_count(), 9);
        assert!(ParamSet::from_flat(&spec, &flat[..8]).is_err());
    }

    #[test]
    fn paramset_arithmetic() {
        let spec = toy_spec();
        let mut acc = ParamSet::zeros(&spec);
        let ones = ParamSet::from_flat(&spec, &[1.0; 9]).unwrap();
        acc.add_scaled(&ones, 0.25).unwrap();
        acc.add_scaled(&ones, 0.75).unwrap();
        assert_eq!(acc.tensor(0), &[1.0; 6]);
        acc.scale(2.0);
        assert_eq!(acc.tensor(1), &[2.0; 3]);
        assert!((acc.l2_norm() - (9.0f64 * 4.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn paramset_mismatch_errors() {
        let spec = toy_spec();
        let mut a = ParamSet::zeros(&spec);
        let b = ParamSet { tensors: vec![vec![0.0; 6]] };
        assert!(a.add_scaled(&b, 1.0).is_err());
    }

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0; 6], &[2, 3]).is_ok());
        assert!(literal_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(literal_i32(&[1; 4], &[4]).is_ok());
        assert!(literal_i32(&[1; 3], &[4]).is_err());
    }
}
