//! Scoped-thread fan-out for CPU-parallel build stages (std-only; the
//! offline build has no rayon).
//!
//! The primary consumer is the sharded fleet-instance pipeline
//! ([`crate::sched::shard`]): per-shard class dedup is embarrassingly
//! parallel, so [`build_fleet_sharded`] fans the shard ranges out over
//! scoped threads and runs the exact cross-shard merge on the caller's
//! thread. Results are always collected **in input order**, so parallel
//! execution cannot perturb any deterministic contract downstream.

use crate::error::Result;
use crate::sched::fleet::FleetInstance;
use crate::sched::instance::Instance;
use crate::sched::shard::{self, ShardClasses, ShardStats};

/// Available CPU parallelism (1 when undetectable).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item on up to `max_workers` scoped threads,
/// returning results **in input order** (worker scheduling can never
/// reorder them). Items are split into contiguous chunks, one per
/// worker; with one worker (or one item) everything runs inline on the
/// caller's thread.
///
/// Panics in `f` propagate to the caller (the scope joins every worker).
pub fn parallel_map<T, R, F>(items: Vec<T>, max_workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let workers = max_workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Contiguous chunks, ceil-sized so every item lands in some chunk.
    let chunk_len = n.div_ceil(workers);
    let mut chunks: Vec<Vec<T>> = Vec::with_capacity(workers);
    let mut it = items.into_iter();
    loop {
        let chunk: Vec<T> = it.by_ref().take(chunk_len).collect();
        if chunk.is_empty() {
            break;
        }
        chunks.push(chunk);
    }
    let f = &f;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                s.spawn(move || chunk.into_iter().map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("pool worker panicked"));
        }
    });
    out
}

/// A detached worker computing one value in the background — the
/// threading primitive behind the coordinator's pipelined round driver:
/// a [`crate::coordinator::RoundBackend`] kicks its training leg off in
/// `begin_train` (e.g. [`crate::coordinator::SimBackend`] with a
/// simulated device latency) and joins it in `finish_train`, leaving the
/// coordinator thread free to speculatively schedule the next round in
/// between.
///
/// Unlike [`parallel_map`] this is *not* scoped: the closure must own its
/// inputs (`'static`), which is exactly the shape a backend's staged
/// round plan has.
#[derive(Debug)]
pub struct BackgroundTask<T> {
    handle: std::thread::JoinHandle<T>,
}

impl<T: Send + 'static> BackgroundTask<T> {
    /// Start computing `f` on a background thread.
    pub fn spawn<F>(f: F) -> Self
    where
        F: FnOnce() -> T + Send + 'static,
    {
        Self { handle: std::thread::spawn(f) }
    }

    /// Block until the value is ready. Panics in `f` propagate here.
    pub fn join(self) -> T {
        self.handle.join().expect("background task panicked")
    }
}

/// Concurrent sharded fleet construction: per-shard class dedup on scoped
/// threads ([`crate::sched::shard::dedup_slots`]), then the exact
/// cross-shard merge. Bit-for-bit identical to
/// [`FleetInstance::from_flat`] — see the shard module's exactness
/// contract. `workers = 0` uses the machine's available parallelism.
pub fn build_fleet_sharded(
    inst: &Instance,
    shards: usize,
    workers: usize,
) -> Result<(FleetInstance, ShardStats)> {
    build_fleet_sharded_traced(inst, shards, workers, None)
}

/// [`build_fleet_sharded`] with optional per-worker span capture for the
/// tracing layer: when `spans` is `Some`, each shard's dedup records its
/// `(start_ns, end_ns)` offsets (one pair per shard, in shard order) on
/// a clock anchored just before the fan-out. The offsets are pure
/// telemetry — the built fleet is bit-for-bit identical either way, and
/// with `spans = None` no clock is read at all.
pub fn build_fleet_sharded_traced(
    inst: &Instance,
    shards: usize,
    workers: usize,
    spans: Option<&mut Vec<(u64, u64)>>,
) -> Result<(FleetInstance, ShardStats)> {
    inst.validate()?;
    let plan = shard::ShardPlan::contiguous(inst.n(), shards);
    let workers = if workers == 0 { default_workers() } else { workers };
    let ranges: Vec<std::ops::Range<usize>> = plan.ranges().to_vec();
    let record = spans.is_some();
    let anchor = std::time::Instant::now();
    let clock = |on: bool| -> u64 {
        if on {
            anchor.elapsed().as_nanos().min(u64::MAX as u128) as u64
        } else {
            0
        }
    };
    let results: Vec<(ShardClasses, u64, u64)> =
        parallel_map(ranges, workers, |r| {
            let start_ns = clock(record);
            let table = shard::dedup_slots(&inst.costs, &inst.lower, &inst.upper, r);
            (table, start_ns, clock(record))
        });
    let mut tables = Vec::with_capacity(results.len());
    if let Some(spans) = spans {
        spans.reserve(results.len());
        for (table, start_ns, end_ns) in results {
            spans.push((start_ns, end_ns));
            tables.push(table);
        }
    } else {
        tables.extend(results.into_iter().map(|(table, _, _)| table));
    }
    shard::merge_with_stats(inst.tasks, tables, plan.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::costs::CostFn;

    #[test]
    fn parallel_map_preserves_input_order() {
        let items: Vec<usize> = (0..103).collect();
        for workers in [1usize, 2, 3, 8, 200] {
            let out = parallel_map(items.clone(), workers, |x| x * 2);
            assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        }
        assert!(parallel_map(Vec::<usize>::new(), 4, |x: usize| x).is_empty());
    }

    #[test]
    fn parallel_build_matches_from_flat_bit_for_bit() {
        let n = 500;
        let costs: Vec<CostFn> = (0..n)
            .map(|i| CostFn::Affine { fixed: 0.0, per_task: 1.0 + (i % 7) as f64 })
            .collect();
        let inst =
            Instance::new(300, vec![0; n], vec![4; n], costs).unwrap();
        let flat = FleetInstance::from_flat(&inst).unwrap();
        for (shards, workers) in [(1, 1), (4, 2), (8, 0), (16, 3), (700, 4)] {
            let (built, stats) =
                build_fleet_sharded(&inst, shards, workers).unwrap();
            assert_eq!(stats.shards, shards.max(1));
            assert_eq!(built.digest(), flat.digest());
            assert_eq!(built.n_classes(), 7);
        }
    }

    #[test]
    fn traced_build_captures_one_span_per_shard() {
        let n = 64;
        let costs: Vec<CostFn> = (0..n)
            .map(|i| CostFn::Affine { fixed: 0.0, per_task: 1.0 + (i % 5) as f64 })
            .collect();
        let inst = Instance::new(40, vec![0; n], vec![4; n], costs).unwrap();
        let (plain, _) = build_fleet_sharded(&inst, 4, 2).unwrap();
        let mut spans = Vec::new();
        let (traced, stats) =
            build_fleet_sharded_traced(&inst, 4, 2, Some(&mut spans)).unwrap();
        assert_eq!(stats.shards, 4);
        assert_eq!(spans.len(), 4, "one span per shard");
        for &(s, e) in &spans {
            assert!(e >= s);
        }
        assert_eq!(traced.digest(), plain.digest(), "telemetry-only");
    }

    #[test]
    fn background_task_returns_its_value() {
        let task = BackgroundTask::spawn(|| (0..100u64).sum::<u64>());
        assert_eq!(task.join(), 4950);
    }

    #[test]
    fn invalid_instances_are_rejected_before_fanout() {
        let bad = Instance {
            tasks: 10,
            lower: vec![0],
            upper: vec![3],
            costs: vec![CostFn::Affine { fixed: 0.0, per_task: 1.0 }],
        };
        assert!(build_fleet_sharded(&bad, 4, 2).is_err());
    }
}
