//! TOML-subset parser.
//!
//! Supports: `key = value` pairs, `[section]` / `[nested.section]` headers,
//! strings (`"..."` with standard escapes), integers, floats, booleans,
//! homogeneous arrays (`[1, 2, 3]`), and `#` comments. This covers the
//! experiment configuration files in `configs/`.

use std::collections::BTreeMap;

use crate::error::{FedError, Result};

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    /// As &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As usize (non-negative int).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array.
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }

    /// Table field lookup.
    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        match self {
            TomlValue::Table(t) => t.get(key),
            _ => None,
        }
    }
}

/// Parse a TOML document into a root table.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut root: BTreeMap<String, TomlValue> = BTreeMap::new();
    let mut section: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(hdr) = line.strip_prefix('[') {
            let hdr = hdr
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?;
            section = hdr.split('.').map(|s| s.trim().to_string()).collect();
            if section.iter().any(|s| s.is_empty()) {
                return Err(err(lineno, "empty section name"));
            }
            // materialize empty table
            insert_path(&mut root, &section, None, lineno)?;
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(value.trim(), lineno)?;
        let mut path = section.clone();
        path.push(key.to_string());
        insert_path(&mut root, &path, Some(value), lineno)?;
    }
    Ok(root)
}

fn err(lineno: usize, msg: &str) -> FedError {
    FedError::Config(format!("TOML line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn insert_path(
    root: &mut BTreeMap<String, TomlValue>,
    path: &[String],
    value: Option<TomlValue>,
    lineno: usize,
) -> Result<()> {
    let mut cur = root;
    for (i, part) in path.iter().enumerate() {
        let last = i == path.len() - 1;
        if last {
            match value {
                Some(ref v) => {
                    if cur.contains_key(part) {
                        return Err(err(lineno, &format!("duplicate key '{part}'")));
                    }
                    cur.insert(part.clone(), v.clone());
                }
                None => {
                    cur.entry(part.clone())
                        .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
                }
            }
            return Ok(());
        }
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        match entry {
            TomlValue::Table(t) => cur = t,
            _ => return Err(err(lineno, &format!("'{part}' is not a table"))),
        }
    }
    Ok(())
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return Ok(TomlValue::Str(unescape(inner, lineno)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .unwrap()
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    // number: int if no '.', 'e', 'E'
    let is_float = s.contains('.') || s.contains('e') || s.contains('E');
    if is_float {
        s.parse::<f64>()
            .map(TomlValue::Float)
            .map_err(|_| err(lineno, &format!("bad float '{s}'")))
    } else {
        s.replace('_', "")
            .parse::<i64>()
            .map(TomlValue::Int)
            .map_err(|_| err(lineno, &format!("bad integer '{s}'")))
    }
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str, lineno: usize) -> Result<String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            _ => return Err(err(lineno, "bad escape")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let doc = parse(
            r#"
            top = 1
            [a]
            s = "hi"        # comment
            f = 2.5
            b = true
            [a.deep]
            arr = [1, 2, 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("top").unwrap().as_usize(), Some(1));
        let a = doc.get("a").unwrap();
        assert_eq!(a.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(a.get("f").unwrap().as_f64(), Some(2.5));
        assert_eq!(a.get("b").unwrap().as_bool(), Some(true));
        let arr = a.get("deep").unwrap().get("arr").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_usize(), Some(3));
    }

    #[test]
    fn string_with_hash_and_escape() {
        let doc = parse(r#"k = "a#b\nc""#).unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some("a#b\nc"));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]").unwrap();
        let m = doc.get("m").unwrap().as_array().unwrap();
        assert_eq!(m[1].as_array().unwrap()[0].as_usize(), Some(3));
    }

    #[test]
    fn negative_and_underscored_numbers() {
        let doc = parse("a = -5\nb = 1_000\nc = -1.5e3").unwrap();
        assert_eq!(doc.get("a"), Some(&TomlValue::Int(-5)));
        assert_eq!(doc.get("b"), Some(&TomlValue::Int(1000)));
        assert_eq!(doc.get("c").unwrap().as_f64(), Some(-1500.0));
    }

    #[test]
    fn errors() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("k = \"unterminated").is_err());
        assert!(parse("dup = 1\ndup = 2").is_err());
        assert!(parse("just a line").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = parse("e = []").unwrap();
        assert_eq!(doc.get("e").unwrap().as_array().unwrap().len(), 0);
    }
}
