//! Configuration system: a TOML-subset parser plus typed experiment
//! configuration structs (the offline build has no `serde`/`toml`).
//!
//! The supported TOML subset covers what experiment files need: top-level
//! and nested `[tables]`, `key = value` with strings, integers, floats,
//! booleans, and homogeneous arrays, plus `#` comments.

pub mod toml;

use crate::error::{FedError, Result};
use toml::TomlValue;

/// Scheduler policy selection (mirrors `--algo`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Classify the instance and pick the cheapest optimal algorithm
    /// (Table 2 of the paper).
    Auto,
    Mc2mkp,
    MarIn,
    MarCo,
    MarDecUn,
    MarDec,
    Uniform,
    Random,
    Proportional,
    Greedy,
    Olar,
}

impl std::str::FromStr for Policy {
    type Err = FedError;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "auto" => Policy::Auto,
            "mc2mkp" | "dp" => Policy::Mc2mkp,
            "marin" => Policy::MarIn,
            "marco" => Policy::MarCo,
            "mardecun" => Policy::MarDecUn,
            "mardec" => Policy::MarDec,
            "uniform" => Policy::Uniform,
            "random" => Policy::Random,
            "proportional" => Policy::Proportional,
            "greedy" => Policy::Greedy,
            "olar" => Policy::Olar,
            other => return Err(FedError::Config(format!("unknown policy '{other}'"))),
        })
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Policy::Auto => "auto",
            Policy::Mc2mkp => "mc2mkp",
            Policy::MarIn => "marin",
            Policy::MarCo => "marco",
            Policy::MarDecUn => "mardecun",
            Policy::MarDec => "mardec",
            Policy::Uniform => "uniform",
            Policy::Random => "random",
            Policy::Proportional => "proportional",
            Policy::Greedy => "greedy",
            Policy::Olar => "olar",
        };
        f.write_str(s)
    }
}

/// Full experiment configuration for `fedzero train`.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// FL rounds to run.
    pub rounds: usize,
    /// Fleet size n.
    pub devices: usize,
    /// Mini-batches to distribute per round (T).
    pub tasks_per_round: usize,
    /// Scheduler policy.
    pub policy: Policy,
    /// Model artifact name (key into artifacts/manifest.json).
    pub model: String,
    /// RNG seed for fleet + data.
    pub seed: u64,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Fraction of devices sampled per round (FedAvg's C).
    pub participation: f64,
    /// Dirichlet alpha for non-IID label split.
    pub dirichlet_alpha: f64,
    /// Minimum participation (lower limit) per selected device.
    pub min_tasks: usize,
    /// Over-representation guard: no device may receive more than this
    /// fraction of a round's tasks (the upper-limit recommendation of the
    /// paper's §6 — energy-optimal schedules otherwise concentrate work on
    /// one device, whose non-IID shard then dominates the global model).
    /// Relaxed automatically if the capped capacity cannot absorb `T`.
    pub max_share: f64,
    /// Convergence target on training loss (early stop), if any.
    pub target_loss: Option<f64>,
    /// Worker threads for client execution.
    pub workers: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            rounds: 50,
            devices: 16,
            tasks_per_round: 64,
            policy: Policy::Auto,
            model: "mlp".into(),
            seed: 7,
            artifacts_dir: "artifacts".into(),
            participation: 1.0,
            dirichlet_alpha: 0.5,
            min_tasks: 0,
            max_share: 0.25,
            target_loss: None,
            workers: 1,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file (all keys optional; defaults otherwise).
    ///
    /// ```toml
    /// [train]
    /// rounds = 100
    /// devices = 32
    /// tasks_per_round = 128
    /// policy = "mc2mkp"
    /// model = "transformer"
    /// seed = 42
    /// participation = 0.5
    /// dirichlet_alpha = 0.1
    /// min_tasks = 1
    /// target_loss = 0.5
    /// workers = 4
    /// ```
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml::parse(text)?;
        let mut cfg = TrainConfig::default();
        let t = match doc.get("train") {
            Some(TomlValue::Table(t)) => t.clone(),
            _ => doc.clone(),
        };
        if let Some(v) = t.get("rounds") {
            cfg.rounds = v.as_usize().ok_or_else(|| bad("rounds"))?;
        }
        if let Some(v) = t.get("devices") {
            cfg.devices = v.as_usize().ok_or_else(|| bad("devices"))?;
        }
        if let Some(v) = t.get("tasks_per_round") {
            cfg.tasks_per_round = v.as_usize().ok_or_else(|| bad("tasks_per_round"))?;
        }
        if let Some(v) = t.get("policy") {
            cfg.policy = v.as_str().ok_or_else(|| bad("policy"))?.parse()?;
        }
        if let Some(v) = t.get("model") {
            cfg.model = v.as_str().ok_or_else(|| bad("model"))?.to_string();
        }
        if let Some(v) = t.get("seed") {
            cfg.seed = v.as_usize().ok_or_else(|| bad("seed"))? as u64;
        }
        if let Some(v) = t.get("artifacts_dir") {
            cfg.artifacts_dir = v.as_str().ok_or_else(|| bad("artifacts_dir"))?.to_string();
        }
        if let Some(v) = t.get("participation") {
            cfg.participation = v.as_f64().ok_or_else(|| bad("participation"))?;
        }
        if let Some(v) = t.get("dirichlet_alpha") {
            cfg.dirichlet_alpha = v.as_f64().ok_or_else(|| bad("dirichlet_alpha"))?;
        }
        if let Some(v) = t.get("min_tasks") {
            cfg.min_tasks = v.as_usize().ok_or_else(|| bad("min_tasks"))?;
        }
        if let Some(v) = t.get("max_share") {
            cfg.max_share = v.as_f64().ok_or_else(|| bad("max_share"))?;
        }
        if let Some(v) = t.get("target_loss") {
            cfg.target_loss = Some(v.as_f64().ok_or_else(|| bad("target_loss"))?);
        }
        if let Some(v) = t.get("workers") {
            cfg.workers = v.as_usize().ok_or_else(|| bad("workers"))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<()> {
        if self.devices == 0 {
            return Err(FedError::Config("devices must be > 0".into()));
        }
        if self.tasks_per_round == 0 {
            return Err(FedError::Config("tasks_per_round must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.participation) || self.participation == 0.0 {
            return Err(FedError::Config("participation must be in (0, 1]".into()));
        }
        if self.dirichlet_alpha <= 0.0 {
            return Err(FedError::Config("dirichlet_alpha must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.max_share) || self.max_share == 0.0 {
            return Err(FedError::Config("max_share must be in (0, 1]".into()));
        }
        if self.workers == 0 {
            return Err(FedError::Config("workers must be > 0".into()));
        }
        Ok(())
    }
}

fn bad(key: &str) -> FedError {
    FedError::Config(format!("bad type for key '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn full_file_parses() {
        let text = r#"
            # experiment
            [train]
            rounds = 100
            devices = 32
            tasks_per_round = 128
            policy = "mc2mkp"
            model = "transformer"
            seed = 42
            participation = 0.5
            dirichlet_alpha = 0.1
            min_tasks = 1
            target_loss = 0.5
            workers = 4
        "#;
        let cfg = TrainConfig::from_toml(text).unwrap();
        assert_eq!(cfg.rounds, 100);
        assert_eq!(cfg.policy, Policy::Mc2mkp);
        assert_eq!(cfg.model, "transformer");
        assert_eq!(cfg.target_loss, Some(0.5));
        assert_eq!(cfg.workers, 4);
    }

    #[test]
    fn flat_file_without_section() {
        let cfg = TrainConfig::from_toml("rounds = 3\ndevices = 2\n").unwrap();
        assert_eq!(cfg.rounds, 3);
        assert_eq!(cfg.devices, 2);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(TrainConfig::from_toml("participation = 0.0").is_err());
        assert!(TrainConfig::from_toml("policy = \"nope\"").is_err());
        assert!(TrainConfig::from_toml("devices = 0").is_err());
        assert!(TrainConfig::from_toml("rounds = \"x\"").is_err());
    }

    #[test]
    fn policy_roundtrip() {
        for p in ["auto", "mc2mkp", "marin", "marco", "mardecun", "mardec",
                  "uniform", "random", "proportional", "greedy", "olar"] {
            let parsed: Policy = p.parse().unwrap();
            assert_eq!(parsed.to_string(), p);
        }
    }
}
