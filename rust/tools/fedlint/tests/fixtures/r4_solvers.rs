//! A miniature solver registry for the R4 fixture.

macro_rules! fn_solver {
    ($name:literal) => {
        pub fn registered() -> &'static str {
            $name
        }
    };
}

fn_solver!("exact");
fn_solver!("missing");

pub struct Auto;

impl Auto {
    fn name(&self) -> &'static str {
        "auto"
    }
}
