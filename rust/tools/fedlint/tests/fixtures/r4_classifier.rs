//! The R4 fixture classifier: it names `exact` and `auto` but not the
//! third registered solver, so exactly one R4 violation is expected.

pub fn classify(name: &str) -> &'static str {
    match name {
        "exact" => "optimal",
        "auto" => "delegates",
        _ => "unknown",
    }
}
