//! Seeded R3 violations plus near-miss names that must not fire.

pub fn commit(value: Option<u32>) -> u32 {
    value.unwrap()
}

pub fn commit_msg(value: Option<u32>) -> u32 {
    value.expect("present")
}

pub fn abort() {
    panic!("boom");
}

pub fn near_miss(value: Option<u32>) -> u32 {
    value.unwrap_or_default()
}
