//! Clean fixture: allow-annotated probe-only map, blessed arithmetic,
//! error-propagating commit path, metrics kept out of the digest.

// fedlint: allow(R1) — probe-only index: reads use `get`, iteration
// never happens, so ordering cannot leak into any digest.
use std::collections::HashMap;

// fedlint: allow(R1) — same probe-only index as above.
pub fn probe(map: &HashMap<u64, usize>, key: u64) -> Option<usize> {
    map.get(&key).copied()
}

pub fn t_prime(tasks: usize, sum_l: usize) -> usize {
    tasks.saturating_sub(sum_l)
}

pub fn commit(value: Option<u32>) -> Result<u32, String> {
    value.ok_or_else(|| "missing".to_string())
}

pub struct Stats {
    pub merge_ns: u64,
}

pub fn digest(tasks: u64) -> u64 {
    tasks.wrapping_mul(0x0100_0000_01b3)
}
