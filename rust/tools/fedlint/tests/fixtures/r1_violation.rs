//! Seeded R1 violations: one per check family.

use std::collections::HashMap;
use std::time::Instant;

pub fn lookup_order(map: &HashMap<u64, usize>) -> Vec<u64> {
    map.keys().copied().collect()
}

pub fn stamp() -> Instant {
    Instant::now()
}

pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

pub fn leak(tracer: &mut dyn Tracer) {
    tracer.span_at("phase");
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    fn exempt() -> HashSet<u64> {
        HashSet::new()
    }
}
