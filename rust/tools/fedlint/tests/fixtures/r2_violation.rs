//! Seeded R2 violations plus a test-region exemption proof.

pub fn t_prime(tasks: usize, sum_l: usize) -> usize {
    tasks - sum_l
}

pub fn widen(upper: usize) -> usize {
    upper + 1
}

pub fn fine(upper: usize, lower: usize) -> usize {
    upper.saturating_sub(lower)
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt() {
        let upper = 5;
        assert_eq!(upper - 1, 4);
    }
}
