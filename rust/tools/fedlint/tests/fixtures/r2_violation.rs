//! Seeded R2 violations plus a test-region exemption proof.

pub fn t_prime(tasks: usize, sum_l: usize) -> usize {
    tasks - sum_l
}

pub fn widen(upper: usize) -> usize {
    upper + 1
}

pub fn fine(upper: usize, lower: usize) -> usize {
    upper.saturating_sub(lower)
}

pub fn cap_search(lo_ok: usize, hi_bad: usize) -> usize {
    lo_ok + (hi_bad - lo_ok) / 2
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt() {
        let upper = 5;
        assert_eq!(upper - 1, 4);
    }
}
