//! Seeded R5 violations: metrics state flowing into a digest fn.

pub struct Stats {
    pub incr_hits: u64,
    pub merge_ns: u64,
}

pub fn digest(stats: &Stats) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    h ^= stats.incr_hits;
    h ^= stats.merge_ns;
    h ^= stats.incr_hits.rotate_left(7);
    h
}

pub fn report(stats: &Stats) -> u64 {
    stats.incr_hits ^ stats.merge_ns
}
