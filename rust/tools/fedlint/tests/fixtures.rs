//! Fixture-driven liveness tests: every rule must fire exactly where
//! seeded, the clean file must stay clean (with its allow annotations
//! counted), and the JSON report schema must stay stable.

use std::path::Path;

use fedlint::config::Config;
use fedlint::report::Report;

const CONFIG: &str = r#"
[r1]
modules = ["r1_violation.rs", "clean.rs"]
idents = ["Tracer", "span_at"]

[r2]
modules = ["r2_violation.rs", "clean.rs"]
idents = ["lower", "upper", "tasks", "sum_l", "lo_ok", "hi_bad"]

[r3]
modules = ["r3_violation.rs", "clean.rs"]

[r4]
solver_file = "r4_solvers.rs"
classifier_files = ["r4_classifier.rs"]

[r5]
modules = ["."]
digest_fns = ["digest"]
prefixes = ["incr_", "pipeline_", "shard_"]
suffixes = ["_ns"]
"#;

fn report() -> Report {
    let cfg = Config::parse(CONFIG).expect("fixture config parses");
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    fedlint::run(&root, &cfg).expect("fixture scan succeeds")
}

#[test]
fn every_rule_fires_exactly_where_seeded() {
    let r = report();
    let got: Vec<(&str, &str, usize)> =
        r.violations.iter().map(|v| (v.rule, v.file.as_str(), v.line)).collect();
    let want = vec![
        ("R1", "r1_violation.rs", 3),
        ("R1", "r1_violation.rs", 4),
        ("R1", "r1_violation.rs", 6),
        ("R1", "r1_violation.rs", 7),
        ("R1", "r1_violation.rs", 10),
        ("R1", "r1_violation.rs", 11),
        ("R1", "r1_violation.rs", 15),
        ("R1", "r1_violation.rs", 18),
        ("R1", "r1_violation.rs", 19),
        ("R2", "r2_violation.rs", 4),
        ("R2", "r2_violation.rs", 8),
        ("R2", "r2_violation.rs", 16),
        ("R3", "r3_violation.rs", 4),
        ("R3", "r3_violation.rs", 8),
        ("R3", "r3_violation.rs", 12),
        ("R4", "r4_solvers.rs", 12),
        ("R5", "r5_violation.rs", 10),
        ("R5", "r5_violation.rs", 11),
        ("R5", "r5_violation.rs", 12),
    ];
    assert_eq!(got, want);
}

#[test]
fn checks_name_the_violation_family() {
    let r = report();
    let find = |file: &str, line: usize| {
        r.violations
            .iter()
            .find(|v| v.file == file && v.line == line)
            .map(|v| v.check)
            .unwrap_or("absent")
    };
    assert_eq!(find("r1_violation.rs", 3), "unordered-container");
    assert_eq!(find("r1_violation.rs", 4), "wall-clock");
    assert_eq!(find("r1_violation.rs", 7), "map-iteration");
    assert_eq!(find("r1_violation.rs", 15), "float-accumulation");
    assert_eq!(find("r1_violation.rs", 18), "telemetry-leak");
    assert_eq!(find("r1_violation.rs", 19), "telemetry-leak");
    assert_eq!(find("r2_violation.rs", 4), "raw-capacity-arith");
    assert_eq!(find("r2_violation.rs", 16), "raw-capacity-arith");
    assert_eq!(find("r3_violation.rs", 4), "unwrap");
    assert_eq!(find("r3_violation.rs", 12), "panic-macro");
    assert_eq!(find("r4_solvers.rs", 12), "unclassified-solver");
    assert_eq!(find("r5_violation.rs", 11), "metrics-into-digest");
}

#[test]
fn clean_file_is_clean_and_allows_are_counted() {
    let r = report();
    assert!(r.violations.iter().all(|v| v.file != "clean.rs"));
    assert_eq!(r.allows_used, 2, "both clean.rs annotations suppress a finding");
    assert_eq!(r.files_scanned, 7);
}

#[test]
fn json_schema_is_stable() {
    let r = report();
    let json = r.to_json();
    let head = "{\"version\":1,\"files_scanned\":7,\"allows_used\":2,\"violations\":[";
    assert!(json.starts_with(head), "schema header changed: {json}");
    let keys = ["\"rule\":", "\"check\":", "\"file\":", "\"line\":", "\"snippet\":", "\"message\":"];
    for key in keys {
        assert_eq!(json.matches(key).count(), 19, "{key} must appear once per violation");
    }
    assert!(json.trim_end().ends_with("]}"));
}
