//! CLI entry point: `fedlint [--format text|json] [--config PATH] <scan-root>`.

#![deny(unsafe_code)]
#![warn(rust_2018_idioms)]

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use fedlint::config::Config;

const USAGE: &str = "\
usage: fedlint [--format text|json] [--config fedlint.toml] <scan-root>

Scans <scan-root> recursively for .rs files and applies the repo rules
R1-R5 declared in fedlint.toml (looked up in the current directory
unless --config is given). Exit codes: 0 clean, 1 violations found,
2 usage/config/io error.
";

fn main() -> ExitCode {
    let mut json = false;
    let mut config_path: Option<PathBuf> = None;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--format" => match args.next().as_deref() {
                Some("json") => json = true,
                Some("text") => json = false,
                other => return usage(&format!("--format expects text|json, got {other:?}")),
            },
            "--config" => match args.next() {
                Some(p) => config_path = Some(PathBuf::from(p)),
                None => return usage("--config expects a path"),
            },
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                return usage(&format!("unknown flag {other}"));
            }
            other => {
                if root.is_some() {
                    return usage("exactly one scan root expected");
                }
                root = Some(PathBuf::from(other));
            }
        }
    }
    let Some(root) = root else {
        return usage("missing scan root (e.g. rust/src)");
    };
    let config_path = config_path.unwrap_or_else(|| PathBuf::from("fedlint.toml"));
    let text = match fs::read_to_string(&config_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("fedlint: cannot read {}: {e}", config_path.display());
            return ExitCode::from(2);
        }
    };
    let cfg = match Config::parse(&text) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fedlint: {e}");
            return ExitCode::from(2);
        }
    };
    let report = match fedlint::run(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fedlint: scan of {} failed: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if json {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.to_text());
    }
    if report.violations.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("fedlint: {msg}\n{USAGE}");
    ExitCode::from(2)
}
