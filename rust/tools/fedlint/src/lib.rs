//! fedlint — repo-specific determinism & soundness lints.
//!
//! The fedzero reproduction guarantees bit-for-bit journal digests
//! across `--shards`/`--pipeline`/`--incremental` and exact solver
//! equivalence; those guarantees rest on invariants no compiler checks.
//! fedlint enforces the static half of them (rules R1–R5, declared in
//! the repo-root `fedlint.toml`) so the whole violation class is caught
//! before CI runs a single test. See EXPERIMENTS.md §Static analysis
//! for the rule table and the allow-annotation policy.

#![deny(unsafe_code)]
#![warn(rust_2018_idioms)]

pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use config::Config;
use report::Report;

/// Scan every `.rs` file under `root` (recursively, in sorted order)
/// and apply the configured rules. The returned report is sorted and
/// ready to print.
pub fn run(root: &Path, cfg: &Config) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut report = Report::default();
    for path in &files {
        let text = fs::read_to_string(path)?;
        let rel = path.strip_prefix(root).unwrap_or(path);
        let rel = rel.to_string_lossy().replace('\\', "/");
        let file = lexer::scan(&text);
        rules::check_file(&rel, &file, cfg, &mut report);
        report.files_scanned += 1;
    }
    rules::check_r4(root, cfg, &mut report);
    report.sort();
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
