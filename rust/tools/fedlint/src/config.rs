//! `fedlint.toml` — which rule applies where.
//!
//! The config language is the tiny TOML subset the repo actually needs
//! (`[section]`, `key = "str"`, `key = ["a", "b"]` on one line), parsed
//! by hand because the authoring environment has no crates.io access.

use std::fmt;

/// Parsed rule configuration. Paths are relative to the scan root
/// (`rust/src`); a module entry names either a single file
/// (`sched/fleet.rs`) or a directory prefix (`store`). The special
/// entry `"."` matches every scanned file.
#[derive(Debug, Default, Clone)]
pub struct Config {
    /// R1: digest-feeding modules (no unordered iteration / wall clock /
    /// ambient RNG / float accumulation).
    pub r1_modules: Vec<String>,
    /// R1: extra banned identifiers (beyond the built-in container/
    /// clock/RNG set) — e.g. the telemetry layer's types and span
    /// methods, which must never reach digest-feeding modules.
    pub r1_idents: Vec<String>,
    /// R2: modules where raw `+`/`-` on capacity idents is banned.
    pub r2_modules: Vec<String>,
    /// R2: the capacity/lower-sum identifiers the ban applies to.
    pub r2_idents: Vec<String>,
    /// R3: commit-path modules (no unwrap/expect/panic).
    pub r3_modules: Vec<String>,
    /// R4: the file defining the solver registry.
    pub r4_solver_file: String,
    /// R4: classifier files that must name every registered solver.
    /// Entries may use `../` to reach out of the scan root (the
    /// differential suites live in `rust/tests/`).
    pub r4_classifier_files: Vec<String>,
    /// R5: modules scanned for metrics-only fields inside digest fns.
    pub r5_modules: Vec<String>,
    /// R5: the digest-feeding function names.
    pub r5_digest_fns: Vec<String>,
    /// R5: metrics-only field name prefixes.
    pub r5_prefixes: Vec<String>,
    /// R5: metrics-only field name suffixes.
    pub r5_suffixes: Vec<String>,
}

/// A config parse failure with its (1-based) line.
#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "fedlint.toml:{}: {}", self.line, self.message)
    }
}

impl Config {
    /// Parse the config text. Unknown sections and keys are errors so a
    /// typo cannot silently disable a rule.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (idx, raw_line) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = raw_line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                match section.as_str() {
                    "r1" | "r2" | "r3" | "r4" | "r5" => {}
                    other => {
                        return Err(err(lineno, format!("unknown section [{other}]")));
                    }
                }
                continue;
            }
            let (key, value) = match line.split_once('=') {
                Some((k, v)) => (k.trim(), v.trim()),
                None => return Err(err(lineno, format!("expected `key = value`: {line}"))),
            };
            match (section.as_str(), key) {
                ("r1", "modules") => cfg.r1_modules = parse_list(value, lineno)?,
                ("r1", "idents") => cfg.r1_idents = parse_list(value, lineno)?,
                ("r2", "modules") => cfg.r2_modules = parse_list(value, lineno)?,
                ("r2", "idents") => cfg.r2_idents = parse_list(value, lineno)?,
                ("r3", "modules") => cfg.r3_modules = parse_list(value, lineno)?,
                ("r4", "solver_file") => cfg.r4_solver_file = parse_str(value, lineno)?,
                ("r4", "classifier_files") => {
                    cfg.r4_classifier_files = parse_list(value, lineno)?;
                }
                ("r5", "modules") => cfg.r5_modules = parse_list(value, lineno)?,
                ("r5", "digest_fns") => cfg.r5_digest_fns = parse_list(value, lineno)?,
                ("r5", "prefixes") => cfg.r5_prefixes = parse_list(value, lineno)?,
                ("r5", "suffixes") => cfg.r5_suffixes = parse_list(value, lineno)?,
                (sec, key) => {
                    return Err(err(lineno, format!("unknown key `{key}` in [{sec}]")));
                }
            }
        }
        Ok(cfg)
    }

    /// Does a scan-root-relative path fall under any of `modules`?
    pub fn in_modules(path: &str, modules: &[String]) -> bool {
        modules.iter().any(|m| under(path, m))
    }
}

fn under(path: &str, module: &str) -> bool {
    module == "."
        || path == module
        || (path.starts_with(module) && path.as_bytes().get(module.len()) == Some(&b'/'))
}

fn err(line: usize, message: String) -> ConfigError {
    ConfigError { line, message }
}

fn parse_str(value: &str, line: usize) -> Result<String, ConfigError> {
    let inner = value
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| err(line, format!("expected a quoted string, got {value}")))?;
    Ok(inner.to_string())
}

fn parse_list(value: &str, line: usize) -> Result<Vec<String>, ConfigError> {
    let inner = value
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| err(line, format!("expected a one-line [..] list, got {value}")))?;
    let mut out = Vec::new();
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        out.push(parse_str(part, line)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_strings_and_lists() {
        let cfg = Config::parse(
            "# comment\n[r1]\nmodules = [\"store\", \"util/hash.rs\"]\n\n[r4]\nsolver_file = \"sched/solver.rs\"\n",
        )
        .unwrap();
        assert_eq!(cfg.r1_modules, vec!["store", "util/hash.rs"]);
        assert_eq!(cfg.r4_solver_file, "sched/solver.rs");
    }

    #[test]
    fn rejects_unknown_sections_and_keys() {
        assert!(Config::parse("[r9]\n").is_err());
        assert!(Config::parse("[r1]\nmodule = [\"store\"]\n").is_err());
    }

    #[test]
    fn module_matching_is_prefix_by_path_component() {
        let mods = vec!["store".to_string(), "sched/fleet.rs".to_string()];
        assert!(Config::in_modules("store/journal.rs", &mods));
        assert!(Config::in_modules("sched/fleet.rs", &mods));
        assert!(!Config::in_modules("storefront/x.rs", &mods));
        assert!(!Config::in_modules("sched/fleet_extra.rs", &mods));
        assert!(Config::in_modules("anything.rs", &[".".to_string()]));
    }
}
