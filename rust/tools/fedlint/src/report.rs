//! Findings and the two output formats (human text, stable JSON).

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule id: `R1`..`R5`.
    pub rule: &'static str,
    /// The specific check within the rule, e.g. `map-iteration`.
    pub check: &'static str,
    /// Scan-root-relative path.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// The offending source line, trimmed.
    pub snippet: String,
    /// What is wrong and what the blessed alternative is.
    pub message: String,
}

/// A whole run's findings.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    /// Findings suppressed by `fedlint: allow(...)` annotations — counted
    /// so dead annotations are visible in review.
    pub allows_used: usize,
    pub violations: Vec<Violation>,
}

/// JSON schema version; bump when the shape of the report changes.
pub const SCHEMA_VERSION: u32 = 1;

impl Report {
    /// Deterministic order: by file, then line, then rule.
    pub fn sort(&mut self) {
        self.violations.sort_by(order);
    }

    /// Human-readable listing, one block per violation.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!(
                "{}:{}: [{}/{}] {}\n",
                v.file, v.line, v.rule, v.check, v.message
            ));
            out.push_str(&format!("    {}\n", v.snippet));
        }
        out.push_str(&format!(
            "fedlint: {} file(s) scanned, {} violation(s), {} allow(s) used\n",
            self.files_scanned,
            self.violations.len(),
            self.allows_used
        ));
        out
    }

    /// Machine-readable report. The schema is covered by fixture tests;
    /// bump [`SCHEMA_VERSION`] on any shape change.
    pub fn to_json(&self) -> String {
        let mut out = format!("{{\"version\":{SCHEMA_VERSION},");
        out.push_str(&format!(
            "\"files_scanned\":{},\"allows_used\":{},\"violations\":[",
            self.files_scanned, self.allows_used
        ));
        for (i, v) in self.violations.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"rule\":{},\"check\":{},\"file\":{},\"line\":{},\"snippet\":{},\"message\":{}}}",
                json_str(v.rule),
                json_str(v.check),
                json_str(&v.file),
                v.line,
                json_str(&v.snippet),
                json_str(&v.message)
            ));
        }
        out.push_str("]}\n");
        out
    }
}

fn order(a: &Violation, b: &Violation) -> std::cmp::Ordering {
    (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
}

/// Escape a string into a JSON literal.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_is_escaped_and_versioned() {
        let mut r = Report { files_scanned: 1, ..Report::default() };
        r.violations.push(Violation {
            rule: "R1",
            check: "map-iteration",
            file: "a.rs".into(),
            line: 3,
            snippet: "say \"hi\"".into(),
            message: "no".into(),
        });
        let json = r.to_json();
        assert!(json.starts_with("{\"version\":1,"));
        assert!(json.contains("\\\"hi\\\""));
        assert!(json.trim_end().ends_with("}]}"));
    }

    #[test]
    fn sort_orders_by_file_then_line() {
        let mk = |file: &str, line: usize| Violation {
            rule: "R1",
            check: "c",
            file: file.into(),
            line,
            snippet: String::new(),
            message: String::new(),
        };
        let mut r = Report::default();
        r.violations = vec![mk("b.rs", 1), mk("a.rs", 9), mk("a.rs", 2)];
        r.sort();
        let order: Vec<(String, usize)> =
            r.violations.iter().map(|v| (v.file.clone(), v.line)).collect();
        assert_eq!(order, vec![("a.rs".into(), 2), ("a.rs".into(), 9), ("b.rs".into(), 1)]);
    }
}
