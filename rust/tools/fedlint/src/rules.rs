//! The five repo rules (see `fedlint.toml` and EXPERIMENTS.md §Static
//! analysis).
//!
//! All scans run over the masked code from [`crate::lexer`]: comments
//! and literal contents are already blanked, and `#[cfg(test)]` /
//! `#[test]` / `macro_rules!` regions are excluded at the emit seam.
//! Findings can be suppressed per rule with a comment annotation:
//!
//! ```text
//! // fedlint: allow(R1) — probe-only map, reads never iterate.
//! use std::collections::HashMap;
//! ```
//!
//! An annotation covers its own line plus the next line carrying code,
//! so a two-line justification comment still reaches its target.

use std::fs;
use std::path::Path;

use crate::config::Config;
use crate::lexer::{self, SourceFile};
use crate::report::{Report, Violation};

/// Suppressions parsed from a file's comments.
pub struct Allows {
    /// (rule, covered line) pairs.
    covered: Vec<(String, usize)>,
}

impl Allows {
    pub fn parse(file: &SourceFile) -> Allows {
        let mut covered = Vec::new();
        for (idx, comment) in file.comments.iter().enumerate() {
            let line = idx + 1;
            let mut from = 0usize;
            while let Some(pos) = comment[from..].find("fedlint: allow(") {
                let at = from + pos + "fedlint: allow(".len();
                from = at;
                let Some(close) = comment[at..].find(')') else { break };
                let rule = comment[at..at + close].trim().to_string();
                covered.push((rule.clone(), line));
                // Cover the next code-bearing line too: annotations sit in
                // comments, whose masked code is blank.
                let mut next = line + 1;
                while next <= file.code.len() {
                    if !file.code[next - 1].trim().is_empty() {
                        covered.push((rule.clone(), next));
                        break;
                    }
                    next += 1;
                }
            }
        }
        Allows { covered }
    }

    pub fn covers(&self, rule: &str, line: usize) -> bool {
        self.covered.iter().any(|(r, l)| r == rule && *l == line)
    }
}

struct Ctx<'a> {
    path: &'a str,
    file: &'a SourceFile,
    allows: &'a Allows,
}

impl Ctx<'_> {
    /// Record a finding unless the line is in a test/macro region or an
    /// allow annotation covers it (counted, so dead allows show up in
    /// review as a zero count).
    fn emit(
        &self,
        report: &mut Report,
        rule: &'static str,
        check: &'static str,
        line: usize,
        message: String,
    ) {
        if self.file.skip.get(line - 1).copied().unwrap_or(false) {
            return;
        }
        if self.allows.covers(rule, line) {
            report.allows_used += 1;
            return;
        }
        let snippet = match self.file.raw.get(line - 1) {
            Some(s) => s.trim().to_string(),
            None => String::new(),
        };
        report.violations.push(Violation {
            rule,
            check,
            file: self.path.to_string(),
            line,
            snippet,
            message,
        });
    }
}

/// Apply R1/R2/R3/R5 to one scanned file (R4 is cross-file; see
/// [`check_r4`]).
pub fn check_file(path: &str, file: &SourceFile, cfg: &Config, report: &mut Report) {
    let allows = Allows::parse(file);
    let ctx = Ctx { path, file, allows: &allows };
    if Config::in_modules(path, &cfg.r1_modules) {
        r1(&ctx, cfg, report);
    }
    if Config::in_modules(path, &cfg.r2_modules) {
        r2(&ctx, cfg, report);
    }
    if Config::in_modules(path, &cfg.r3_modules) {
        r3(&ctx, report);
    }
    if Config::in_modules(path, &cfg.r5_modules) {
        r5(&ctx, cfg, report);
    }
}

/// R1 — digest-feeding modules must be deterministic: no unordered
/// containers (even probe-only use must carry a justifying allow), no
/// wall-clock reads, no ambient RNG, no float accumulation, and none of
/// the extra configured identifiers (the telemetry layer's types and
/// span methods — tracing is pure output and stays out of digest code).
fn r1(ctx: &Ctx<'_>, cfg: &Config, report: &mut Report) {
    const IDENTS: [(&str, &str, &str); 6] = [
        ("HashMap", "unordered-container", "justify probe-only use or use a sorted structure"),
        ("HashSet", "unordered-container", "justify probe-only use or use a sorted structure"),
        ("Instant", "wall-clock", "timings are metrics-only and never reach digest inputs"),
        ("SystemTime", "wall-clock", "timings are metrics-only and never reach digest inputs"),
        ("thread_rng", "ambient-rng", "randomness must flow from the seeded campaign RNG"),
        ("from_entropy", "ambient-rng", "randomness must flow from the seeded campaign RNG"),
    ];
    const METHODS: [&str; 6] = [
        ".keys(",
        ".values(",
        ".values_mut(",
        ".into_keys(",
        ".into_values(",
        ".drain(",
    ];
    const FLOAT_ACC: [&str; 3] = ["fold(0.0", ".sum::<f32>()", ".sum::<f64>()"];
    for (idx, code) in ctx.file.code.iter().enumerate() {
        let line = idx + 1;
        for (ident, check, why) in IDENTS {
            if has_ident(code, ident) {
                let msg = format!("`{ident}` in a digest-feeding module; {why}");
                ctx.emit(report, "R1", check, line, msg);
            }
        }
        for method in METHODS {
            if code.contains(method) {
                let msg = format!("unordered iteration `{method})` in a digest-feeding module");
                ctx.emit(report, "R1", "map-iteration", line, msg);
            }
        }
        for pat in FLOAT_ACC {
            if code.contains(pat) {
                let msg = format!(
                    "float accumulation `{pat}` in a digest-feeding module; accumulate in \
                     integers or document an order-fixed fold"
                );
                ctx.emit(report, "R1", "float-accumulation", line, msg);
            }
        }
        for ident in &cfg.r1_idents {
            if has_ident(code, ident) {
                let msg = format!(
                    "telemetry identifier `{ident}` in a digest-feeding module; tracing is \
                     pure output and must stay out of digest code"
                );
                ctx.emit(report, "R1", "telemetry-leak", line, msg);
            }
        }
    }
}

/// R2 — capacity/lower-sum arithmetic in `sched/` must go through the
/// blessed helpers (`effective_limits`, `saturating_*`, `wrapping_*`),
/// never raw `+`/`-`.
fn r2(ctx: &Ctx<'_>, cfg: &Config, report: &mut Report) {
    for (idx, code) in ctx.file.code.iter().enumerate() {
        if !has_raw_add_sub(code) {
            continue;
        }
        let Some(ident) = cfg.r2_idents.iter().find(|id| has_ident(code, id)) else {
            continue;
        };
        let msg = format!(
            "raw `+`/`-` on a line touching capacity ident `{ident}`; use \
             saturating/wrapping helpers or effective_limits"
        );
        ctx.emit(report, "R2", "raw-capacity-arith", idx + 1, msg);
    }
}

/// R3 — commit paths surface failures as `FedError` (or poison); they
/// never abort.
fn r3(ctx: &Ctx<'_>, report: &mut Report) {
    const PATTERNS: [(&str, &str); 6] = [
        (".unwrap()", "unwrap"),
        (".expect(", "expect"),
        ("panic!", "panic-macro"),
        ("unreachable!", "panic-macro"),
        ("todo!", "panic-macro"),
        ("unimplemented!", "panic-macro"),
    ];
    for (idx, code) in ctx.file.code.iter().enumerate() {
        for (pat, check) in PATTERNS {
            if has_pattern(code, pat) {
                let msg = format!("`{pat}` in a commit path; return FedError or poison the store");
                ctx.emit(report, "R3", check, idx + 1, msg);
            }
        }
    }
}

/// R4 — every solver the registry constructs must be named by each
/// classifier the differential suites key on, or a new solver would
/// silently skip its equivalence class.
pub fn check_r4(root: &Path, cfg: &Config, report: &mut Report) {
    if cfg.r4_solver_file.is_empty() {
        return;
    }
    let solver_path = root.join(&cfg.r4_solver_file);
    let Ok(text) = fs::read_to_string(&solver_path) else {
        report.violations.push(Violation {
            rule: "R4",
            check: "missing-solver-file",
            file: cfg.r4_solver_file.clone(),
            line: 1,
            snippet: String::new(),
            message: format!("cannot read solver registry file {}", solver_path.display()),
        });
        return;
    };
    let file = lexer::scan(&text);
    let allows = Allows::parse(&file);
    let registered = registered_solvers(&file);
    if registered.is_empty() {
        let msg = "no registered solver names found; the R4 extractor no longer matches the \
                   registry idiom — fix the extractor, do not delete the rule";
        report.violations.push(Violation {
            rule: "R4",
            check: "no-names-found",
            file: cfg.r4_solver_file.clone(),
            line: 1,
            snippet: String::new(),
            message: msg.to_string(),
        });
        return;
    }
    let ctx = Ctx { path: &cfg.r4_solver_file, file: &file, allows: &allows };
    for cls in &cfg.r4_classifier_files {
        let Ok(cls_text) = fs::read_to_string(root.join(cls)) else {
            report.violations.push(Violation {
                rule: "R4",
                check: "missing-classifier",
                file: cls.clone(),
                line: 1,
                snippet: String::new(),
                message: format!("cannot read classifier file {cls}"),
            });
            continue;
        };
        let cls_file = lexer::scan(&cls_text);
        for (name, line) in &registered {
            if !cls_file.strings.iter().any(|(_, v)| v == name) {
                let message = format!(
                    "solver \"{name}\" is registered here but never named in classifier \
                     {cls}; the differential suites would silently skip it"
                );
                ctx.emit(report, "R4", "unclassified-solver", *line, message);
            }
        }
    }
}

/// Registered solver names: the first string literal on each
/// `fn_solver!(..)` invocation line, plus the first string literal
/// inside each hand-written `fn name` body (test and `macro_rules!`
/// regions excluded).
fn registered_solvers(file: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (idx, code) in file.code.iter().enumerate() {
        let line = idx + 1;
        if file.skip[idx] || !code.contains("fn_solver!") {
            continue;
        }
        if let Some((_, v)) = file.strings.iter().find(|(l, _)| *l == line) {
            out.push((v.clone(), line));
        }
    }
    for (first, last) in fn_bodies(file, "name") {
        if let Some((l, v)) = file.strings.iter().find(|(l, _)| (first..=last).contains(l)) {
            out.push((v.clone(), *l));
        }
    }
    out.sort();
    out.dedup();
    out
}

/// R5 — metrics-only state (configured prefixes/suffixes) must never
/// appear inside a digest-feeding function body.
fn r5(ctx: &Ctx<'_>, cfg: &Config, report: &mut Report) {
    for fn_name in &cfg.r5_digest_fns {
        for (first, last) in fn_bodies(ctx.file, fn_name) {
            for line in first..=last {
                let code = &ctx.file.code[line - 1];
                for ident in idents(code) {
                    let metrics = cfg.r5_prefixes.iter().any(|p| ident.starts_with(p.as_str()))
                        || cfg.r5_suffixes.iter().any(|s| ident.ends_with(s.as_str()));
                    if metrics {
                        let message = format!(
                            "metrics-only field `{ident}` inside `{fn_name}`; digests must \
                             exclude wall-clock/throughput state"
                        );
                        ctx.emit(report, "R5", "metrics-into-digest", line, message);
                    }
                }
            }
        }
    }
}

/// (first_line, last_line) of every non-test `fn <name>` body in the
/// file. Bodiless declarations (trait methods ending in `;`) are
/// skipped.
fn fn_bodies(file: &SourceFile, name: &str) -> Vec<(usize, usize)> {
    let flat = file.code.join("\n");
    let bytes = flat.as_bytes();
    let needle = format!("fn {name}");
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = flat[from..].find(&needle) {
        let start = from + pos;
        from = start + needle.len();
        if start > 0 && is_ident_byte(bytes[start - 1]) {
            continue;
        }
        let after = start + needle.len();
        if after < bytes.len() && is_ident_byte(bytes[after]) {
            continue;
        }
        let first = lexer::line_of(&flat, start);
        if file.skip.get(first - 1).copied().unwrap_or(false) {
            continue;
        }
        let mut j = after;
        let mut open = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(close) = open.and_then(|o| lexer::close_brace(&flat, o)) {
            out.push((first, lexer::line_of(&flat, close)));
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whole-identifier occurrence of `ident` in masked code.
fn has_ident(code: &str, ident: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(pos) = code[from..].find(ident) {
        let at = from + pos;
        from = at + ident.len();
        let left_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + ident.len();
        let right_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if left_ok && right_ok {
            return true;
        }
    }
    false
}

/// Occurrence of `pat` with a non-identifier byte on its left (method
/// patterns start with `.`, which is its own boundary).
fn has_pattern(code: &str, pat: &str) -> bool {
    let mut from = 0usize;
    while let Some(pos) = code[from..].find(pat) {
        let at = from + pos;
        from = at + pat.len();
        if pat.starts_with('.') || at == 0 || !is_ident_byte(code.as_bytes()[at - 1]) {
            return true;
        }
    }
    false
}

/// A binary `+`/`-` whose left operand ends in an identifier byte or a
/// closing bracket. `->`, unary minus and float exponents (`1e-9`) do
/// not count.
fn has_raw_add_sub(code: &str) -> bool {
    let bytes = code.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'+' && b != b'-' {
            continue;
        }
        if b == b'-' && bytes.get(i + 1) == Some(&b'>') {
            continue;
        }
        if b == b'-'
            && i >= 2
            && (bytes[i - 1] == b'e' || bytes[i - 1] == b'E')
            && bytes[i - 2].is_ascii_digit()
        {
            continue;
        }
        let prev = bytes[..i].iter().rev().find(|&&p| p != b' ');
        if prev.is_some_and(|&p| is_ident_byte(p) || p == b')' || p == b']') {
            return true;
        }
    }
    false
}

/// Identifier tokens of a masked code line, in order.
fn idents(code: &str) -> Vec<&str> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i].is_ascii_alphabetic() || bytes[i] == b'_' {
            let start = i;
            while i < bytes.len() && is_ident_byte(bytes[i]) {
                i += 1;
            }
            out.push(&code[start..i]);
        } else {
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_add_sub_skips_arrows_unary_and_exponents() {
        assert!(has_raw_add_sub("let x = upper - lower;"));
        assert!(has_raw_add_sub("f(a[i] + 1)"));
        assert!(!has_raw_add_sub("fn f() -> usize {"));
        assert!(!has_raw_add_sub("let x = -1;"));
        assert!(!has_raw_add_sub("let eps = 1e-9;"));
        assert!(has_raw_add_sub("sum += l;"));
    }

    #[test]
    fn ident_matching_is_whole_word() {
        assert!(has_ident("use std::collections::HashMap;", "HashMap"));
        assert!(!has_ident("let upper_bound = 3;", "upper"));
        assert!(!has_ident("let my_upper = 3;", "upper"));
        assert!(has_ident("let upper = 3;", "upper"));
    }

    #[test]
    fn pattern_matching_needs_a_left_boundary_for_macros() {
        assert!(has_pattern("panic!(\"no\")", "panic!"));
        assert!(!has_pattern("dont_panic!(\"no\")", "panic!"));
        assert!(has_pattern("x.unwrap()", ".unwrap()"));
        assert!(!has_pattern("x.unwrap_or(0)", ".unwrap()"));
    }

    #[test]
    fn allows_cover_their_line_and_the_next_code_line() {
        let src = "// fedlint: allow(R1) — probe-only, reads use get,\n// never iteration.\nuse std::collections::HashMap;\n";
        let file = lexer::scan(src);
        let allows = Allows::parse(&file);
        assert!(allows.covers("R1", 1));
        assert!(allows.covers("R1", 3), "skips the comment continuation line");
        assert!(!allows.covers("R2", 3), "rule-specific");
    }

    #[test]
    fn fn_bodies_skips_bodiless_declarations_and_tests() {
        let src = "trait T {\n    fn name(&self) -> &'static str;\n}\nstruct S;\nimpl S {\n    fn name(&self) -> &'static str {\n        \"s\"\n    }\n}\n";
        let file = lexer::scan(src);
        assert_eq!(fn_bodies(&file, "name"), vec![(6, 8)]);
    }
}
