//! A minimal Rust source scanner — the token layer under every rule.
//!
//! fedlint runs in an offline build environment with no `syn`, so instead
//! of an AST it produces a **masked** view of each file: comments and
//! string/char-literal contents are blanked to spaces (string delimiters
//! survive, so token structure stays visible), comment text is kept per
//! line (allow annotations live there), string literal values are
//! recorded (rule R4 reads solver names from them), and `#[cfg(test)]` /
//! `#[test]` / `macro_rules!` regions are brace-matched so rules can skip
//! them. The rules are line-oriented and the tree is rustfmt-normalized,
//! which is what makes this masking sufficient in practice.

/// One scanned source file.
#[derive(Debug, Default)]
pub struct SourceFile {
    /// Original lines (report snippets).
    pub raw: Vec<String>,
    /// Masked code lines: comments and literal contents blanked to
    /// spaces. Non-ASCII code characters are blanked too, so byte-level
    /// scans never split a UTF-8 boundary.
    pub code: Vec<String>,
    /// Comment text accumulated per line.
    pub comments: Vec<String>,
    /// String literal values with the (1-based) line each starts on, in
    /// source order.
    pub strings: Vec<(usize, String)>,
    /// Per-line flag: inside a `#[cfg(test)]` / `#[test]` /
    /// `macro_rules!` region.
    pub skip: Vec<bool>,
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    /// Inside a string literal; `Some(n)` is a raw string closed by `"`
    /// followed by `n` hashes.
    Str(Option<usize>),
    StrEscape,
    Char,
    CharEscape,
}

/// Scan `text` into its masked view.
pub fn scan(text: &str) -> SourceFile {
    let chars: Vec<char> = text.chars().collect();
    let mut file = SourceFile {
        raw: text.lines().map(str::to_string).collect(),
        ..SourceFile::default()
    };
    let mut code = String::new();
    let mut comment = String::new();
    let mut value = String::new();
    let mut value_line = 0usize;
    let mut state = State::Code;
    let n = chars.len();
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            match state {
                State::LineComment => state = State::Code,
                State::Str(_) => value.push('\n'),
                State::StrEscape => {
                    value.push('\n');
                    state = State::Str(None);
                }
                _ => {}
            }
            file.code.push(std::mem::take(&mut code));
            file.comments.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    code.push_str("  ");
                    state = State::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    code.push_str("  ");
                    state = State::BlockComment(1);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    code.push('"');
                    state = State::Str(raw_hashes(&chars, i));
                    value_line = file.code.len() + 1;
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    if is_char_literal(&chars, i) {
                        code.push(' ');
                        state = State::Char;
                    } else {
                        // A lifetime tick: ordinary code.
                        code.push('\'');
                    }
                    i += 1;
                    continue;
                }
                code.push(if c.is_ascii() { c } else { ' ' });
                i += 1;
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(depth + 1);
                    comment.push(' ');
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
            }
            State::Str(raw) => {
                if c == '"' {
                    let hashes = raw.unwrap_or(0);
                    let closes = (0..hashes).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closes {
                        code.push('"');
                        for _ in 0..hashes {
                            code.push(' ');
                        }
                        file.strings.push((value_line, std::mem::take(&mut value)));
                        state = State::Code;
                        i += 1 + hashes;
                        continue;
                    }
                    value.push('"');
                    code.push(' ');
                    i += 1;
                    continue;
                }
                if c == '\\' && raw.is_none() {
                    value.push(c);
                    code.push(' ');
                    state = State::StrEscape;
                    i += 1;
                    continue;
                }
                value.push(c);
                code.push(' ');
                i += 1;
            }
            State::StrEscape => {
                value.push(c);
                code.push(' ');
                state = State::Str(None);
                i += 1;
            }
            State::Char => {
                if c == '\'' {
                    state = State::Code;
                } else if c == '\\' {
                    state = State::CharEscape;
                }
                code.push(' ');
                i += 1;
            }
            State::CharEscape => {
                code.push(' ');
                state = State::Char;
                i += 1;
            }
        }
    }
    if !code.is_empty() || file.code.len() < file.raw.len() {
        file.code.push(code);
        file.comments.push(comment);
    }
    while file.code.len() < file.raw.len() {
        file.code.push(String::new());
        file.comments.push(String::new());
    }
    file.skip = mark_regions(&file.code);
    file
}

/// At an opening quote: `Some(n)` when this is a raw string prefixed by
/// `r` (or `br`) and `n` hashes.
fn raw_hashes(chars: &[char], quote: usize) -> Option<usize> {
    let mut j = quote;
    let mut hashes = 0usize;
    while j > 0 && chars[j - 1] == '#' {
        hashes += 1;
        j -= 1;
    }
    if j > 0 && chars[j - 1] == 'r' {
        Some(hashes)
    } else {
        None
    }
}

/// At a tick: a char literal (vs a lifetime) iff it is escaped or closed
/// one character later.
fn is_char_literal(chars: &[char], tick: usize) -> bool {
    match chars.get(tick + 1) {
        Some('\\') => true,
        Some(_) => chars.get(tick + 2) == Some(&'\''),
        None => false,
    }
}

/// 1-based line number of a byte position in flattened (newline-joined)
/// masked code.
pub fn line_of(flat: &str, pos: usize) -> usize {
    flat.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Byte position of the `}` closing the `{` at `open` (masked code, so
/// braces inside literals and comments are already blanked).
pub fn close_brace(flat: &str, open: usize) -> Option<usize> {
    let bytes = flat.as_bytes();
    let mut depth = 0usize;
    let mut j = open;
    while j < bytes.len() {
        match bytes[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Mark every line covered by a test or `macro_rules!` item: from the
/// marker through the matching close brace (or through the `;` of a
/// braceless item).
fn mark_regions(code: &[String]) -> Vec<bool> {
    let mut skip = vec![false; code.len()];
    if code.is_empty() {
        return skip;
    }
    let flat = code.join("\n");
    let bytes = flat.as_bytes();
    for marker in ["#[cfg(test)]", "#[cfg(all(test", "#[test]", "macro_rules!"] {
        let mut from = 0usize;
        while let Some(pos) = flat[from..].find(marker) {
            let start = from + pos;
            from = start + marker.len();
            let first = line_of(&flat, start);
            let mut j = start + marker.len();
            let mut open = None;
            while j < bytes.len() {
                match bytes[j] {
                    b'{' => {
                        open = Some(j);
                        break;
                    }
                    b';' => break,
                    _ => {}
                }
                j += 1;
            }
            let last = match open.and_then(|o| close_brace(&flat, o)) {
                Some(close) => line_of(&flat, close),
                None => line_of(&flat, j.min(bytes.len() - 1)),
            };
            for s in skip.iter_mut().take(last).skip(first - 1) {
                *s = true;
            }
        }
    }
    skip
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings_but_keeps_structure() {
        let src = "let x = \"a { b\"; // trailing { note\nlet y = 1;\n";
        let f = scan(src);
        assert_eq!(f.code.len(), 2);
        assert!(!f.code[0].contains('{'), "brace in string must be masked");
        assert!(f.comments[0].contains("trailing"));
        assert_eq!(f.strings, vec![(1, "a { b".to_string())]);
    }

    #[test]
    fn distinguishes_lifetimes_from_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> char {\n    'x'\n}\n";
        let f = scan(src);
        assert!(f.code[0].contains("<'a>"), "lifetimes stay code");
        assert!(!f.code[1].contains('x'), "char literal content is masked");
    }

    #[test]
    fn raw_strings_and_escapes_terminate_correctly() {
        let src = "let a = r#\"quote \" inside\"#;\nlet b = \"esc \\\" here\";\nlet c = 1;\n";
        let f = scan(src);
        assert_eq!(f.strings.len(), 2);
        assert_eq!(f.strings[0].1, "quote \" inside");
        assert!(f.code[2].contains("let c = 1;"), "scanner must resync");
    }

    #[test]
    fn test_regions_are_brace_matched() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn live2() {}\n";
        let f = scan(src);
        assert_eq!(f.skip, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn braceless_attributed_items_cover_only_themselves() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() {}\n";
        let f = scan(src);
        assert_eq!(f.skip, vec![true, true, false]);
    }
}
