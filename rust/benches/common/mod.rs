//! Shared instance generators for the benchmark suite.

// Each bench target compiles its own copy and uses its own subset (e.g.
// dp_ablation only runs Scenario::Arbitrary), so per-target dead-code
// analysis must not gate the shared module.
#![allow(dead_code)]

use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::util::rng::Rng;

/// Scenario shapes matching the paper's Table 2 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Noisy tabulated costs (only the DP is optimal).
    Arbitrary,
    /// Quadratic costs (MarIn's scenario).
    Increasing,
    /// Affine costs (MarCo's scenario).
    Constant,
    /// Concave costs, no effective upper limits (MarDecUn's scenario).
    DecreasingUnlimited,
    /// Concave costs with binding upper limits (MarDec's scenario).
    DecreasingLimited,
}

/// Generate a valid instance of the given scenario with exactly `n`
/// resources and workload `t`.
pub fn generate(scenario: Scenario, n: usize, t: usize, rng: &mut Rng) -> Instance {
    let costs: Vec<CostFn> = (0..n)
        .map(|_| match scenario {
            Scenario::Arbitrary => {
                // Tabulated noisy costs over the full domain [0, t].
                let base = rng.range_f64(0.5, 3.0);
                let mut values = Vec::with_capacity(t + 1);
                values.push(0.0);
                for j in 1..=t {
                    values.push(base * j as f64 * rng.lognormal(0.0, 0.25));
                }
                CostFn::Tabulated { first: 0, values }
            }
            Scenario::Increasing => CostFn::Quadratic {
                fixed: rng.range_f64(0.0, 1.0),
                a: rng.range_f64(0.005, 0.1),
                b: rng.range_f64(0.5, 3.0),
            },
            Scenario::Constant => CostFn::Affine {
                fixed: rng.range_f64(0.0, 1.0),
                per_task: rng.range_f64(0.5, 3.0),
            },
            Scenario::DecreasingUnlimited | Scenario::DecreasingLimited => {
                CostFn::PowerLaw {
                    fixed: 0.0,
                    scale: rng.range_f64(0.5, 3.0),
                    exponent: rng.range_f64(0.3, 0.9),
                }
            }
        })
        .collect();

    let upper: Vec<usize> = match scenario {
        // Unlimited domains: every class spans [0, T], so the DP's
        // Σ|N_i| = n(T+1) and the full O(T²n) shape is visible.
        Scenario::DecreasingUnlimited | Scenario::Arbitrary => vec![t; n],
        Scenario::DecreasingLimited | Scenario::Increasing | Scenario::Constant => {
            // Binding limits averaging ~3T/n so ΣU ≈ 3T > T.
            let avg = (3 * t / n).max(2);
            (0..n)
                .map(|_| rng.range_u64((avg / 2).max(1) as u64, (2 * avg) as u64) as usize)
                .collect()
        }
    };
    // Clamp tabulated domains to the cap (tabulated costs were built over
    // [0, t] so any cap works).
    let lower = vec![0; n];
    let mut upper = upper;
    // Guarantee feasibility.
    loop {
        let cap: usize = upper.iter().map(|&u| u.min(t)).sum();
        if cap >= t {
            break;
        }
        for u in upper.iter_mut() {
            *u += (t / n).max(1);
        }
    }
    Instance::new(t, lower, upper, costs).expect("generated instance valid")
}
