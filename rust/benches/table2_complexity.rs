//! TAB2 — empirical reproduction of the paper's Table 2 ("solutions with
//! the smallest complexity for the variations of our scheduling problem").
//!
//! For each scenario row we sweep the workload size `T` (at fixed `n`) and
//! the resource count `n` (at fixed `T`), time the designated algorithm,
//! and fit log-log slopes. Expected exponents:
//!
//! | algorithm | claimed            | slope vs T | slope vs n |
//! |-----------|--------------------|-----------:|-----------:|
//! | (MC)²MKP  | O(T² n)            |        ~2  |        ~1  |
//! | MarIn     | Θ(n + T log n)     |        ~1  |       <~1  |
//! | MarCo     | Θ(n log n)         |        ~0  |        ~1  |
//! | MarDecUn  | Θ(n)               |        ~0  |        ~1  |
//! | MarDec    | O(T n²)            |        ~1  |        ~2  |
//!
//! (Slopes are asymptotic; small sizes flatten them — the fit quality r²
//! is printed so degenerate fits are visible.)

#[path = "common/mod.rs"]
mod common;

use common::{generate, Scenario};
use fedzero::benchkit::{bench, BenchConfig};
use fedzero::sched::SolverRegistry;
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::table::{fmt_duration, Table};

struct Row {
    algo: &'static str,
    scenario: Scenario,
    claimed: &'static str,
    t_sweep: Vec<usize>,
    n_sweep: Vec<usize>,
    fixed_n: usize,
    fixed_t: usize,
}

fn time_solve(
    registry: &SolverRegistry,
    algo: &str,
    scenario: Scenario,
    n: usize,
    t: usize,
    cfg: &BenchConfig,
) -> f64 {
    let mut rng = Rng::new((n * 1_000_003 + t) as u64);
    let inst = generate(scenario, n, t, &mut rng);
    let mut solve_rng = Rng::new(7);
    let m = bench("solve", cfg, || {
        registry.solve_seeded(algo, &inst, &mut solve_rng).unwrap()
    });
    m.median()
}

fn main() {
    // FEDZERO_BENCH_SMOKE=1: tiny sweeps, quick timing — the CI gate that
    // catches API-level perf regressions without paying the full matrix.
    let smoke = std::env::var("FEDZERO_BENCH_SMOKE").is_ok();
    let rows = vec![
        Row {
            algo: "mc2mkp",
            scenario: Scenario::Arbitrary,
            claimed: "O(T^2 n)",
            t_sweep: vec![128, 256, 512, 1024, 2048],
            n_sweep: vec![4, 8, 16, 32, 64],
            fixed_n: 8,
            fixed_t: 512,
        },
        Row {
            algo: "marin",
            scenario: Scenario::Increasing,
            claimed: "Th(n + T log n)",
            t_sweep: vec![1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18],
            n_sweep: vec![16, 64, 256, 1024, 4096],
            fixed_n: 64,
            fixed_t: 1 << 14,
        },
        Row {
            algo: "marco",
            scenario: Scenario::Constant,
            claimed: "Th(n log n)",
            t_sweep: vec![1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18],
            n_sweep: vec![1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 16],
            fixed_n: 1 << 12,
            fixed_t: 1 << 14,
        },
        Row {
            algo: "mardecun",
            scenario: Scenario::DecreasingUnlimited,
            claimed: "Th(n)",
            t_sweep: vec![1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18],
            n_sweep: vec![1 << 8, 1 << 10, 1 << 12, 1 << 14, 1 << 16],
            fixed_n: 1 << 12,
            fixed_t: 1 << 14,
        },
        Row {
            algo: "mardec",
            scenario: Scenario::DecreasingLimited,
            claimed: "O(T n^2)",
            t_sweep: vec![256, 512, 1024, 2048, 4096],
            n_sweep: vec![4, 8, 16, 32, 64],
            fixed_n: 16,
            fixed_t: 1024,
        },
    ];

    let rows: Vec<Row> = if smoke {
        rows.into_iter()
            .map(|mut r| {
                r.t_sweep.truncate(2);
                r.n_sweep.truncate(2);
                r.fixed_n = r.n_sweep[0];
                r.fixed_t = r.t_sweep[0];
                r
            })
            .collect()
    } else {
        rows
    };
    let cfg = if smoke {
        BenchConfig::quick()
    } else {
        BenchConfig { warmup: 1, iters: 7, min_time_s: 0.02 }
    };
    let registry = SolverRegistry::with_defaults(7);
    let mut table = Table::new(
        "TABLE 2 (empirical): runtime scaling per scenario",
        &["algorithm", "claimed", "slope vs T (r2)", "slope vs n (r2)",
          "t @ (T*, n*)"],
    );

    for row in rows {
        // T sweep at fixed n.
        let mut ts = Vec::new();
        let mut times_t = Vec::new();
        for &t in &row.t_sweep {
            let m = time_solve(&registry, row.algo, row.scenario, row.fixed_n, t, &cfg);
            ts.push(t as f64);
            times_t.push(m);
        }
        let (slope_t, r2_t) = stats::loglog_slope(&ts, &times_t);

        // n sweep at fixed T.
        let mut ns = Vec::new();
        let mut times_n = Vec::new();
        for &n in &row.n_sweep {
            let m = time_solve(&registry, row.algo, row.scenario, n, row.fixed_t, &cfg);
            ns.push(n as f64);
            times_n.push(m);
        }
        let (slope_n, r2_n) = stats::loglog_slope(&ns, &times_n);

        table.rows_str(vec![
            row.algo.to_string(),
            row.claimed.to_string(),
            format!("{slope_t:+.2} ({r2_t:.3})"),
            format!("{slope_n:+.2} ({r2_n:.3})"),
            format!(
                "{} @ (T={}, n={})",
                fmt_duration(*times_t.last().unwrap()),
                row.t_sweep.last().unwrap(),
                row.fixed_n
            ),
        ]);
        eprintln!(
            "[table2] {}: T-sweep {:?} → {:?}",
            row.algo,
            row.t_sweep,
            times_t.iter().map(|s| fmt_duration(*s)).collect::<Vec<_>>()
        );
    }

    table.print();
    println!("Expected: (MC)²MKP ≈ slope 2 vs T / 1 vs n; MarIn ≈ 1 vs T;");
    println!("MarCo & MarDecUn ≈ 0 vs T, ≈ 1 vs n; MarDec ≈ 1 vs T, ≈ 2 vs n.");
}
