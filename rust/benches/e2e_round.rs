//! EX-D — runtime hot path: PJRT step latency and full coordinator round
//! throughput on the AOT artifacts (requires `make artifacts`).

use fedzero::benchkit::{BenchConfig, Report};
use fedzero::config::{Policy, TrainConfig};
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::BehaviorMix;
use fedzero::fl::data::Dataset;
use fedzero::fl::Server;
use fedzero::runtime::{Dtype, ModelRuntime};
use fedzero::util::rng::Rng;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("e2e_round: artifacts/ missing — run `make artifacts` first; skipping.");
        return;
    }

    let cfg = BenchConfig { warmup: 2, iters: 9, min_time_s: 0.02 };

    // ---- per-step PJRT latency ------------------------------------------
    for model in ["mlp", "transformer"] {
        let runtime = match ModelRuntime::load(artifacts, model) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let mut rng = Rng::new(1);
        let ds = Dataset::synth(runtime.spec(), 256, &mut rng);
        let shard = ds.full_shard();
        let batch = ds.batch(runtime.spec(), &shard, &mut rng).unwrap();
        let x = match runtime.spec().input_dtype {
            Dtype::F32 => runtime.input_literal_f32(&batch.x_f32).unwrap(),
            Dtype::S32 => runtime.input_literal_i32(&batch.x_i32).unwrap(),
        };
        let y = runtime.label_literal(&batch.y).unwrap();
        let params = runtime.initial_params();

        let mut report = Report::new(&format!(
            "PJRT step latency — {model} ({} params, batch {})",
            runtime.spec().param_count,
            runtime.spec().batch
        ));
        report.bench("train_step", &cfg, || {
            runtime.train_step(&params, &x, &y).unwrap()
        });
        report.bench("eval_step", &cfg, || {
            runtime.eval_step(&params, &x, &y).unwrap()
        });
        report.print();

        let step_s = report.measurements()[0].median();
        let tput = runtime.spec().batch as f64 / step_s;
        println!("→ {model}: {tput:.0} samples/s single-stream\n");
    }

    // ---- full coordinator round -----------------------------------------
    let round_cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.0 };
    let mut report = Report::new("coordinator round (mlp, 16 devices, T=64)");
    for policy in [Policy::Auto, Policy::Mc2mkp, Policy::Uniform] {
        let cfg_train = TrainConfig {
            rounds: 1,
            devices: 16,
            tasks_per_round: 64,
            model: "mlp".into(),
            policy,
            seed: 5,
            ..TrainConfig::default()
        };
        let mut server =
            Server::new(cfg_train, BehaviorMix::Homogeneous(Behavior::Convex)).unwrap();
        report.bench(&format!("round policy={policy}"), &round_cfg, || {
            server.round().unwrap()
        });
    }
    report.print();
    println!("L3 scheduling is microseconds; the round is dominated by PJRT step");
    println!("execution — the coordinator is not the bottleneck (paper's setting).");
}
