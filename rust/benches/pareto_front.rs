//! Pareto-front construction cost: ε-constraint (this repo) scaling vs the
//! O(n³T³ log nT) bound of the general bi-objective algorithm [28] the
//! paper cites. We cannot run the authors' implementation, so the
//! comparison is to the *bound*: the table reports our measured time and
//! the ratio to a (normalized) cubic-model prediction, showing the
//! structural win of exploiting monotone time functions.

#[path = "common/mod.rs"]
mod common;

use fedzero::benchkit::{bench, BenchConfig};
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::pareto::BiInstance;
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::table::{fmt_duration, Table};

fn tradeoff(n: usize, t: usize, seed: u64) -> BiInstance {
    let mut rng = Rng::new(seed);
    let mut costs = Vec::new();
    let mut time = Vec::new();
    for _ in 0..n {
        let speed = rng.range_f64(0.1, 2.0);
        costs.push(CostFn::Affine { fixed: 0.0, per_task: 2.0 / speed });
        time.push(CostFn::Affine { fixed: 0.0, per_task: speed });
    }
    let energy = Instance::new(t, vec![0; n], vec![t; n], costs).unwrap();
    BiInstance { energy, time }
}

fn main() {
    let cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.0 };
    let mut table = Table::new(
        "Pareto front construction (ε-constraint over (MC)²MKP)",
        &["n", "T", "front points", "time", "time / (nT)^1.x"],
    );
    let mut sizes_t = Vec::new();
    let mut times = Vec::new();
    for (n, t) in [(4usize, 50usize), (8, 50), (8, 100), (16, 100), (16, 200)] {
        let bi = tradeoff(n, t, 3);
        let front = bi.pareto_front().unwrap();
        let m = bench("front", &cfg, || bi.pareto_front().unwrap());
        sizes_t.push((n * t) as f64);
        times.push(m.median());
        table.rows_str(vec![
            n.to_string(),
            t.to_string(),
            front.len().to_string(),
            fmt_duration(m.median()),
            format!("{:.3e}", m.median() / ((n * t) as f64).powf(1.5)),
        ]);
    }
    table.print();
    let (slope, r2) = stats::loglog_slope(&sizes_t, &times);
    println!("empirical exponent vs (n·T): {slope:.2} (r²={r2:.3}) — the cited");
    println!("general-case algorithm scales with exponent 3 in both variables.");
}
