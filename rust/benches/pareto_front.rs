//! Pareto-front construction cost: ε-constraint (this repo) scaling vs the
//! O(n³T³ log nT) bound of the general bi-objective algorithm [28] the
//! paper cites. We cannot run the authors' implementation, so the
//! comparison is to the *bound*: the table reports our measured time and
//! the ratio to a (normalized) cubic-model prediction, showing the
//! structural win of exploiting monotone time functions on the
//! class-deduplicated fleet.

#[path = "common/mod.rs"]
mod common;

use fedzero::benchkit::{bench, BenchConfig};
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::pareto::{BiFleet, TimeModel};
use fedzero::sched::SolverRegistry;
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::table::{fmt_duration, Table};

fn tradeoff(n: usize, t: usize, seed: u64) -> BiFleet {
    let mut rng = Rng::new(seed);
    let mut costs = Vec::new();
    let mut times = Vec::new();
    for _ in 0..n {
        let speed = rng.range_f64(0.1, 2.0);
        costs.push(CostFn::Affine { fixed: 0.0, per_task: 2.0 / speed });
        times.push(TimeModel::affine(speed, 0.0));
    }
    let energy = Instance::new(t, vec![0; n], vec![t; n], costs).unwrap();
    BiFleet::from_flat(&energy, &times).unwrap()
}

fn main() {
    let cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.0 };
    let registry = SolverRegistry::with_defaults(3);
    let mut table = Table::new(
        "Pareto front construction (ε-constraint over (MC)²MKP)",
        &["n", "T", "front points", "time", "time / (nT)^1.x"],
    );
    let mut sizes_t = Vec::new();
    let mut times = Vec::new();
    for (n, t) in [(4usize, 50usize), (8, 50), (8, 100), (16, 100), (16, 200)] {
        let bi = tradeoff(n, t, 3);
        let front = bi.pareto_front(&registry, "mc2mkp").unwrap();
        let m = bench("front", &cfg, || bi.pareto_front(&registry, "mc2mkp").unwrap());
        sizes_t.push((n * t) as f64);
        times.push(m.median());
        table.rows_str(vec![
            n.to_string(),
            t.to_string(),
            front.len().to_string(),
            fmt_duration(m.median()),
            format!("{:.3e}", m.median() / ((n * t) as f64).powf(1.5)),
        ]);
    }
    table.print();
    let (slope, r2) = stats::loglog_slope(&sizes_t, &times);
    println!("empirical exponent vs (n·T): {slope:.2} (r²={r2:.3}) — the cited");
    println!("general-case algorithm scales with exponent 3 in both variables.");
}
