//! FIG1 / FIG2 — regenerate the paper's worked example (§3.1): optimal
//! schedules for T = 5 (Fig. 1) and T = 8 (Fig. 2), printed as Gantt-style
//! charts, plus solve-time measurements for every algorithm on the example.

use fedzero::benchkit::{BenchConfig, Report};
use fedzero::sched::instance::Instance;
use fedzero::sched::{validate, SolverRegistry};
use fedzero::util::rng::Rng;

fn gantt(inst: &Instance, sched: &fedzero::sched::Schedule) {
    for i in 0..inst.n() {
        let x = sched.get(i);
        let bar: String = std::iter::repeat('█').take(x).collect();
        let pad: String = std::iter::repeat('·').take(inst.cap(i) - x).collect();
        println!(
            "  resource {}: {bar}{pad}  x={x}  C({x})={}",
            i + 1,
            inst.costs[i].eval(x)
        );
    }
}

fn main() {
    println!("=== FIG1 & FIG2: paper §3.1 worked example ===\n");
    for (t, expect_x, expect_c, fig) in [
        (5usize, vec![2usize, 3, 0], 7.5, "Fig. 1"),
        (8, vec![1, 2, 5], 11.5, "Fig. 2"),
    ] {
        let inst = Instance::paper_example(t);
        let sched = fedzero::sched::mc2mkp::solve(&inst).unwrap();
        let cost = validate::checked_cost(&inst, &sched).unwrap();
        println!("{fig}: T = {t} → X* = {sched}, ΣC = {cost}");
        gantt(&inst, &sched);
        assert_eq!(sched.assignments(), expect_x.as_slice(), "{fig} schedule");
        assert!((cost - expect_c).abs() < 1e-12, "{fig} cost");
        println!("  matches paper: X* = {expect_x:?}, ΣC = {expect_c} ✓\n");
    }

    println!("greedy-prefix insight (§3.1): optimal T=8 schedule does not");
    println!("contain the optimal T=5 schedule — verified by the asserts above.\n");

    // Solve-time microbenchmarks on the example instance.
    let cfg = BenchConfig::default();
    let registry = SolverRegistry::with_defaults(0);
    let mut report = Report::new("solve time on the §3.1 example (n=3)");
    for policy in ["mc2mkp", "uniform", "proportional", "olar"] {
        for t in [5usize, 8] {
            let inst = Instance::paper_example(t);
            let mut rng = Rng::new(0);
            report.bench(&format!("{policy} T={t}"), &cfg, || {
                registry.solve_seeded(policy, &inst, &mut rng).unwrap()
            });
        }
    }
    report.print();
}
