//! EX-A — total-energy comparison: the paper's optimal schedulers vs
//! baseline policies over the three marginal-cost regimes (and arbitrary
//! tabulated costs), plus solve-time cost of optimality.
//!
//! "Who wins, by roughly what factor": the optimal algorithms define the
//! floor (+0%); baselines pay regime-dependent premiums that GROW with the
//! decreasing-marginal-cost concentration effect.

#[path = "common/mod.rs"]
mod common;

use common::{generate, Scenario};
use fedzero::benchkit::{bench, BenchConfig};
use fedzero::sched::{validate, SolverRegistry};
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::table::{fmt_duration, Table};

const POLICIES: [&str; 6] =
    ["auto", "uniform", "random", "proportional", "greedy", "olar"];

fn main() {
    let scenarios = [
        (Scenario::Increasing, "increasing"),
        (Scenario::Constant, "constant"),
        (Scenario::DecreasingUnlimited, "decreasing (no limits)"),
        (Scenario::DecreasingLimited, "decreasing (limits)"),
        (Scenario::Arbitrary, "arbitrary"),
    ];
    let n = 50usize;
    let t = 500usize;
    let trials = 8u64;
    let cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.01 };
    let registry = SolverRegistry::with_defaults(13);

    for (scenario, name) in scenarios {
        let mut table = Table::new(
            &format!("EX-A: energy vs optimal — {name}, n={n}, T={t}, {trials} trials"),
            &["policy", "mean +%", "max +%", "median solve time"],
        );
        for &policy in &POLICIES {
            let mut overheads = Vec::new();
            let mut solve_times = Vec::new();
            for trial in 0..trials {
                let mut rng = Rng::new(trial * 977 + 13);
                let inst = generate(scenario, n, t, &mut rng);
                let opt = validate::total_cost(
                    &inst,
                    &registry.solve_seeded("mc2mkp", &inst, &mut rng).unwrap(),
                );
                let mut solve_rng = Rng::new(trial);
                let sched = registry
                    .solve_seeded(policy, &inst, &mut solve_rng)
                    .unwrap();
                validate::check(&inst, &sched).unwrap();
                let cost = validate::total_cost(&inst, &sched);
                overheads.push((cost / opt - 1.0) * 100.0);
                if trial == 0 {
                    let m = bench("solve", &cfg, || {
                        registry.solve_seeded(policy, &inst, &mut solve_rng).unwrap()
                    });
                    solve_times.push(m.median());
                }
            }
            let (_, max) = stats::min_max(&overheads);
            table.rows_str(vec![
                policy.to_string(),
                format!("{:+.2}", stats::mean(&overheads)),
                format!("{max:+.2}"),
                fmt_duration(stats::mean(&solve_times)),
            ]);
        }
        table.print();
        println!();
    }
    println!("Shape check: optimal policies at +0% everywhere; baseline premiums");
    println!("largest under decreasing marginal costs (spreading is maximally");
    println!("wasteful when concentration amortizes cost) — the paper's core story.");
}
