//! EX-C — ablation of (MC)²MKP implementation choices (DESIGN.md §Perf):
//!
//! * **flat row-major K/I** (shipped) vs a nested `Vec<Vec<f64>>` layout;
//! * **item-outer / τ-inner loop** (shipped: sequential row scans) vs
//!   τ-outer / item-inner (strided access);
//! * cost of the **backtrack** relative to the DP fill.
//!
//! All variants must produce identical costs — asserted before timing.

#[path = "common/mod.rs"]
mod common;

use common::{generate, Scenario};
use fedzero::benchkit::{BenchConfig, Report};
use fedzero::sched::mc2mkp::{classes_from_instance, dp, solve_classes};
use fedzero::sched::limits;
use fedzero::util::rng::Rng;

/// Item-outer flat DP — the paper's Algorithm-1 loop order (each improving
/// item re-writes cells). This was the originally-shipped variant; the
/// τ-outer rewrite replaced it (see EXPERIMENTS.md §Perf).
fn dp_item_outer_flat(classes: &fedzero::sched::mc2mkp::Classes, cap: usize) -> Vec<f64> {
    let n = classes.classes.len();
    let width = cap + 1;
    let mut k = vec![f64::INFINITY; (n + 1) * width];
    k[0] = 0.0;
    for (r, class) in classes.classes.iter().enumerate() {
        let (prev_rows, cur_rows) = k.split_at_mut((r + 1) * width);
        let prev = &prev_rows[r * width..(r + 1) * width];
        let cur = &mut cur_rows[..width];
        for it in class.iter() {
            if it.weight > cap {
                continue;
            }
            for t in it.weight..=cap {
                let cand = prev[t - it.weight] + it.cost;
                if cand < cur[t] {
                    cur[t] = cand;
                }
            }
        }
    }
    k
}

/// Nested-Vec DP with τ-outer/item-inner loops — the "textbook" layout.
fn dp_nested(classes: &fedzero::sched::mc2mkp::Classes, cap: usize) -> Vec<Vec<f64>> {
    let n = classes.classes.len();
    let mut k = vec![vec![f64::INFINITY; cap + 1]; n + 1];
    k[0][0] = 0.0;
    for (r, class) in classes.classes.iter().enumerate() {
        for tau in 0..=cap {
            let mut best = f64::INFINITY;
            for item in class {
                if item.weight <= tau {
                    let cand = k[r][tau - item.weight] + item.cost;
                    if cand < best {
                        best = cand;
                    }
                }
            }
            k[r + 1][tau] = best;
        }
    }
    k
}

fn main() {
    let sizes = [(8usize, 256usize), (16, 512), (8, 1024)];
    let cfg = BenchConfig { warmup: 1, iters: 9, min_time_s: 0.05 };

    for (n, t) in sizes {
        let mut rng = Rng::new((n * 31 + t) as u64);
        let inst = generate(Scenario::Arbitrary, n, t, &mut rng);
        let tr = limits::remove_lower_limits(&inst);
        let classes = classes_from_instance(&tr.instance);

        // Equivalence check across all three variants.
        let flat = dp(&classes, t);
        let nested = dp_nested(&classes, t);
        let item_outer = dp_item_outer_flat(&classes, t);
        for tau in 0..=t {
            let a = flat.z(n, tau);
            let b = nested[n][tau];
            let c = item_outer[n * (t + 1) + tau];
            assert!(
                (a.is_infinite() && b.is_infinite() && c.is_infinite())
                    || ((a - b).abs() < 1e-9 && (a - c).abs() < 1e-9),
                "variant mismatch at τ={tau}: {a} vs {b} vs {c}"
            );
        }

        let mut report = Report::new(&format!("DP ablation — n={n}, T={t}"));
        report.bench("flat tau-outer (shipped)", &cfg, || dp(&classes, t));
        report.bench("flat item-outer (paper order)", &cfg, || {
            dp_item_outer_flat(&classes, t)
        });
        report.bench("nested Vec, tau-outer", &cfg, || dp_nested(&classes, t));
        report.bench("full solve (dp + backtrack)", &cfg, || {
            solve_classes(&classes, t).unwrap()
        });
        report.print();
        println!();
    }
    println!("The flat τ-outer fill is the shipped choice (single write per cell);");
    println!("the backtrack adds negligible cost over the DP fill.");
}
