//! FLEET — flat per-device vs class-deduplicated solve times.
//!
//! A fleet of `n` devices in `k = 100` classes (multiplicity `n/k` each)
//! is solved twice per marginal algorithm: through the legacy flat path
//! (`O(n)`-ish) and through the class-aware `solve_fleet` path
//! (`O(k)`-ish). The acceptance bar for the redesign is a **≥ 10×**
//! speedup at `n = 10⁵` on at least one marginal algorithm; in practice
//! MarIn/MarCo/MarDecUn all clear it by orders of magnitude.
//!
//! The (MC)²MKP DP is included at the smallest size as a *parity* row:
//! arbitrary costs admit no intra-class shortcut, so the class DP matches
//! the flat DP's arithmetic (the win there is memory — rolling f64 rows,
//! only `u32` backtrack tables at `O(n·T)`), and its speedup is expected
//! to be ~1×.
//!
//! Since the sharded build pipeline, a second scenario times **instance
//! construction itself** on a million-device fleet: single-thread
//! `FleetInstance::from_flat` vs the sharded concurrent build
//! (`runtime::pool::build_fleet_sharded` — partition, per-shard class
//! dedup on scoped threads, exact merge). The full sweep gates the
//! sharded build at **≥ 3×** the single-thread build; every run (smoke
//! included) records the measured ops and speedup ratios into
//! `BENCH_fleet_scale.json` so CI keeps a machine-readable perf
//! trajectory.
//!
//! A third scenario times the **pipelined round driver** end-to-end:
//! the same coordinator campaign, serial vs overlapped (round `r + 1`'s
//! Scheduling speculated while round `r` trains on a background thread
//! whose latency is pegged to a probed serial round). The full sweep
//! gates pipelined round throughput **≥ 1.5×** serial; rows must be
//! bit-identical and every speculation must adopt.
//!
//! A fourth scenario times **incremental round re-derivation**: a
//! million-device fleet where ≤ 1% of devices re-cost per round, built
//! through the persistent class index
//! (`sched::incremental::FleetIndex` — mark dirty, re-classify only the
//! dirty set, derive from live buckets) vs the from-scratch per-round
//! rebuild. Every round's output is digest-asserted identical; the gate
//! is **≥ 5×**, enforced on smoke and full alike (both legs are
//! single-thread CPU work, so few-core runners measure the same ratio).
//!
//! A fifth scenario times **Pareto-front construction** (the deadline
//! work): the class-level ε-constraint sweep — per-τ capping through
//! `BiFleet::capped_fleet` plus Table-2 auto dispatch on the capped
//! instance — vs the flat baseline a caller without class machinery
//! pays: re-cap every device and run the general (MC)²MKP DP at every
//! candidate τ. Per-τ optimal energies are asserted equal (the
//! differential suite proves the stronger property); the gate is
//! **≥ 5×**, enforced on smoke and full alike (both legs are
//! single-thread CPU work).
//!
//! A sixth scenario drives the **networked coordinator service**: the
//! same stored campaign twice — once in-process (`SimBackend`), once
//! served over the loopback transport to a simulated client fleet of
//! `n` devices (10⁵ smoke / 10⁶ full) with injected connection churn —
//! and asserts the two journals carry the *same campaign digest*. The
//! wire bound rides along: the largest schedule-slice frame must stay
//! under a fixed byte budget (the payload names one class and carries
//! one class cost — O(classes), never O(devices)), and a straggler leg
//! with forced deadline misses must still complete its rounds partially.
//!
//! `FEDZERO_BENCH_SMOKE=1` shrinks the sweep to `n = 10³` (solves),
//! `n = 2·10⁵` (build and incremental), `n = 2·10⁴` (pipeline), and
//! `n = 60` (pareto) with quick timing — the CI regression gate. Every gated ratio FAILS the
//! run (non-zero exit) when it regresses below its floor; the
//! build-speedup assertion is full-sweep only (shared CI runners expose
//! too few cores to gate a parallelism ratio honestly), and smoke's
//! pipeline floor is a looser 1.2× tripwire for the same reason.

use std::path::Path;
use std::time::{Duration, Instant};

use fedzero::benchkit::{bench, BenchConfig};
use fedzero::coordinator::{
    BackendState, Coordinator, CoordinatorConfig, ManagedDevice, RoundBackend,
    SimBackend,
};
use fedzero::runtime::pool;
use fedzero::store::journal::{campaign_digest, JournalEntry};
use fedzero::store::{snapshot as snap, CampaignStore};
use fedzero::svc::{loopback_service, ServiceConfig, SimClientsConfig};
use fedzero::sched::costs::CostFn;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::incremental::{from_scratch_round, FleetIndex, RoundParams};
use fedzero::sched::instance::Instance;
use fedzero::sched::pareto::{BiFleet, TimeModel};
use fedzero::sched::{marco, mardecun, marin, mc2mkp, validate, SolverRegistry};
use fedzero::util::json::Json;
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_duration, Table};

const K: usize = 100;

fn build(algo: &str, n: usize, t: usize) -> (FleetInstance, Instance) {
    let mut rng = Rng::new((n as u64).wrapping_mul(0xF1EE7) ^ algo.len() as u64);
    let mut b = FleetInstance::builder().tasks(t);
    for _ in 0..K {
        let (cost, upper) = match algo {
            "marin" => (
                CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 1.0),
                    a: rng.range_f64(0.005, 0.1),
                    b: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            "marco" => (
                CostFn::Affine {
                    fixed: rng.range_f64(0.0, 1.0),
                    per_task: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            "mardecun" => (
                CostFn::PowerLaw {
                    fixed: 0.0,
                    scale: rng.range_f64(0.5, 3.0),
                    exponent: rng.range_f64(0.3, 0.9),
                },
                t,
            ),
            "mc2mkp" => (
                CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 1.0),
                    a: rng.range_f64(0.005, 0.1),
                    b: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            other => panic!("unknown algo {other}"),
        };
        b = b.device_class(cost, 0, upper, n / K);
    }
    let fleet = b.build().expect("bench fleet valid");
    let flat = fleet.to_flat();
    (fleet, flat)
}

/// Drive one stored campaign to completion for the service scenario;
/// returns the wall time, the journal, and the coordinator (for backend
/// stats). Aborted rounds journal too, so the loop always terminates.
fn run_stored_campaign<B: RoundBackend + BackendState>(
    dir: &Path,
    cfg: &CoordinatorConfig,
    fleet: Vec<ManagedDevice>,
    backend: B,
) -> (Duration, Vec<JournalEntry>, Coordinator<B>) {
    let _ = std::fs::remove_dir_all(dir);
    let mut c = Coordinator::new(cfg.clone(), fleet, backend).unwrap();
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(4.0)),
        ("cfg", snap::cfg_to_json(cfg)),
    ]);
    let store = CampaignStore::create(dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
    let t0 = Instant::now();
    while c.rounds_run() < cfg.rounds {
        let _ = c.round_stored();
    }
    let wall = t0.elapsed();
    let entries = CampaignStore::read(dir).unwrap().entries;
    (wall, entries, c)
}

fn main() {
    let smoke = std::env::var("FEDZERO_BENCH_SMOKE").is_ok();
    let sizes: &[usize] = if smoke {
        &[1_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    // Smoke still batches each sample to ≥ ~0.5 ms (the class path solves
    // in microseconds; unbatched medians would be scheduler-noise).
    let cfg = if smoke {
        BenchConfig { warmup: 1, iters: 9, min_time_s: 0.005 }
    } else {
        BenchConfig { warmup: 1, iters: 7, min_time_s: 0.02 }
    };

    let mut table = Table::new(
        &format!("FLEET SCALE: flat vs class-deduplicated solves (k = {K} classes)"),
        &["algorithm", "n", "T", "flat", "class", "dedup", "speedup"],
    );
    let mut worst_marginal_speedup = f64::INFINITY;
    let mut solve_rows: Vec<Json> = Vec::new();

    for &n in sizes {
        let t = 2 * n;
        for algo in ["marin", "marco", "mardecun"] {
            let (fleet, flat) = build(algo, n, t);
            let m_flat = match algo {
                "marin" => bench("flat", &cfg, || marin::solve(&flat).unwrap()),
                "marco" => bench("flat", &cfg, || marco::solve(&flat).unwrap()),
                "mardecun" => {
                    bench("flat", &cfg, || mardecun::solve(&flat).unwrap())
                }
                _ => unreachable!(),
            };
            let m_class = match algo {
                "marin" => bench("class", &cfg, || marin::solve_fleet(&fleet).unwrap()),
                "marco" => bench("class", &cfg, || marco::solve_fleet(&fleet).unwrap()),
                "mardecun" => {
                    bench("class", &cfg, || mardecun::solve_fleet(&fleet).unwrap())
                }
                _ => unreachable!(),
            };
            // Cost of deduplicating a flat instance from scratch — what a
            // caller pays when it does NOT maintain a FleetInstance.
            let m_dedup = bench("dedup", &cfg, || {
                FleetInstance::from_flat(&flat).unwrap()
            });
            let speedup = m_flat.median() / m_class.median().max(1e-12);
            worst_marginal_speedup = worst_marginal_speedup.min(speedup);
            solve_rows.push(Json::obj(vec![
                ("algo", Json::Str(algo.to_string())),
                ("n", Json::Num(n as f64)),
                ("t", Json::Num(t as f64)),
                ("flat_s", Json::Num(m_flat.median())),
                ("class_s", Json::Num(m_class.median())),
                ("dedup_s", Json::Num(m_dedup.median())),
                ("speedup", Json::Num(speedup)),
            ]));
            table.rows_str(vec![
                algo.to_string(),
                n.to_string(),
                t.to_string(),
                fmt_duration(m_flat.median()),
                fmt_duration(m_class.median()),
                fmt_duration(m_dedup.median()),
                format!("{speedup:.0}x"),
            ]);
        }
    }

    // Parity row: the DP has no intra-class shortcut for arbitrary costs.
    {
        let n = sizes[0];
        let t = 2 * n;
        let (fleet, flat) = build("mc2mkp", n, t);
        let m_flat = bench("flat", &cfg, || mc2mkp::solve(&flat).unwrap());
        let m_class = bench("class", &cfg, || mc2mkp::solve_fleet(&fleet).unwrap());
        let speedup = m_flat.median() / m_class.median().max(1e-12);
        table.rows_str(vec![
            "mc2mkp (parity)".to_string(),
            n.to_string(),
            t.to_string(),
            fmt_duration(m_flat.median()),
            fmt_duration(m_class.median()),
            "—".to_string(),
            format!("{speedup:.1}x"),
        ]);
    }

    table.print();

    // ---- sharded million-device instance build ---------------------------
    //
    // What a coordinator round pays *before* any solver runs: turning n
    // devices into a class-deduplicated FleetInstance. Single-thread
    // `from_flat` vs the sharded scoped-thread pipeline (identical output
    // bits — asserted below, and property-tested in
    // tests/shard_equivalence.rs).
    let build_n: usize = if smoke { 200_000 } else { 1_000_000 };
    let build_t = 2 * build_n;
    let workers = pool::default_workers();
    let shards = (workers * 2).max(2);
    let build_cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.0 };
    let (build_fleet, build_flat) = build("marco", build_n, build_t);
    let m_single = bench("from_flat", &build_cfg, || {
        FleetInstance::from_flat(&build_flat).unwrap()
    });
    let m_sharded = bench("sharded", &build_cfg, || {
        pool::build_fleet_sharded(&build_flat, shards, workers).unwrap()
    });
    let (check, _) = pool::build_fleet_sharded(&build_flat, shards, workers).unwrap();
    assert_eq!(
        check.digest(),
        build_fleet.digest(),
        "sharded build must be bit-identical to the direct build"
    );
    let build_speedup = m_single.median() / m_sharded.median().max(1e-12);
    let mut build_table = Table::new(
        &format!(
            "FLEET BUILD: single-thread vs sharded instance construction \
             ({workers} workers, {shards} shards)"
        ),
        &["n", "T", "classes", "single", "sharded", "speedup"],
    );
    build_table.rows_str(vec![
        build_n.to_string(),
        build_t.to_string(),
        build_fleet.n_classes().to_string(),
        fmt_duration(m_single.median()),
        fmt_duration(m_sharded.median()),
        format!("{build_speedup:.1}x"),
    ]);
    build_table.print();

    // ---- pipelined round driver: serial vs overlapped campaigns ----------
    //
    // End-to-end coordinator rounds on an all-unique fleet (k = n, so
    // Scheduling genuinely costs something) over a sim backend whose
    // training leg takes real wall-clock time on a background thread.
    // The training delay is pegged to a probed serial round, so the
    // overlap window is full: the serial loop pays prepare + train per
    // round while the pipelined driver hides the prepare inside the
    // train — the paper setting where device-side work dominates.
    // Correctness rides along: rows must be bit-identical and every
    // speculation must adopt (static fleet, exact sim predictions).
    let pipe_n: usize = if smoke { 20_000 } else { 60_000 };
    let pipe_rounds: usize = if smoke { 6 } else { 10 };
    let pipe_fleet = || -> Vec<ManagedDevice> {
        let mut rng = Rng::new(0x9143_7EED);
        (0..pipe_n)
            .map(|i| {
                ManagedDevice::abstract_resource(
                    i,
                    CostFn::Quadratic {
                        fixed: rng.range_f64(0.0, 1.0),
                        a: rng.range_f64(0.005, 0.1),
                        b: rng.range_f64(0.5, 3.0),
                    },
                    0,
                    8,
                )
            })
            .collect()
    };
    let pipe_cfg = |pipeline: bool| CoordinatorConfig {
        rounds: pipe_rounds,
        tasks_per_round: 4 * pipe_n,
        algo: "marin".into(),
        participation: 1.0,
        max_share: 1.0,
        seed: 91,
        pipeline: pipeline.into(),
        ..CoordinatorConfig::default()
    };
    // Size the training delay from undelayed serial rounds: discard the
    // first (cold caches, first-touch allocation) and take the median of
    // the next three, so one transiently slow probe cannot inflate the
    // delay and make the enforced speedup gate unreachable.
    let round_cost = {
        let mut probe =
            Coordinator::new(pipe_cfg(false), pipe_fleet(), SimBackend::new())
                .unwrap();
        probe.round().unwrap();
        let mut samples: Vec<Duration> = (0..3)
            .map(|_| {
                let t0 = Instant::now();
                probe.round().unwrap();
                t0.elapsed()
            })
            .collect();
        samples.sort();
        samples[1]
    };
    // Slightly under the full round so the pipelined round is bounded by
    // the (comparable) speculation cost, not by idle sleep — the regime
    // where overlap pays most; floored so tiny machines still measure
    // sleep, not noise.
    let train_delay = round_cost.mul_f64(0.9).max(Duration::from_millis(10));
    let run_campaign = |pipeline: bool| {
        let mut c = Coordinator::new(
            pipe_cfg(pipeline),
            pipe_fleet(),
            SimBackend::with_train_delay(train_delay),
        )
        .unwrap();
        let t0 = Instant::now();
        c.run().unwrap();
        let wall = t0.elapsed();
        let rows: Vec<(u64, u64)> = c
            .log()
            .rows()
            .iter()
            .map(|r| (r.energy_j.to_bits(), r.loss.to_bits()))
            .collect();
        let hits = c.metrics().counter("pipeline_hits");
        (wall, rows, hits)
    };
    let (serial_wall, serial_rows, _) = run_campaign(false);
    let (piped_wall, piped_rows, pipe_hits) = run_campaign(true);
    assert_eq!(
        serial_rows, piped_rows,
        "pipelined campaign must be bit-identical to serial"
    );
    assert_eq!(
        pipe_hits as usize,
        pipe_rounds - 1,
        "static sim fleet: every speculation must be adopted"
    );
    let pipe_speedup =
        serial_wall.as_secs_f64() / piped_wall.as_secs_f64().max(1e-9);
    let mut pipe_table = Table::new(
        &format!(
            "PIPELINED ROUNDS: serial vs overlapped campaigns \
             (n = {pipe_n}, {pipe_rounds} rounds, train ≈ {})",
            fmt_duration(train_delay.as_secs_f64())
        ),
        &["mode", "wall", "rounds/s", "speedup"],
    );
    pipe_table.rows_str(vec![
        "serial".into(),
        fmt_duration(serial_wall.as_secs_f64()),
        format!("{:.1}", pipe_rounds as f64 / serial_wall.as_secs_f64()),
        "1.0x".into(),
    ]);
    pipe_table.rows_str(vec![
        "pipelined".into(),
        fmt_duration(piped_wall.as_secs_f64()),
        format!("{:.1}", pipe_rounds as f64 / piped_wall.as_secs_f64()),
        format!("{pipe_speedup:.2}x"),
    ]);
    pipe_table.print();

    // ---- incremental round re-derivation: persistent index vs rebuild ----
    //
    // What a coordinator round pays to *build* its instance when the
    // fleet barely changed: 1% of devices re-cost per round. The
    // persistent index re-classifies only the dirty set and derives the
    // round instance from live buckets; the baseline re-buckets all n
    // device signatures from scratch. Outputs are digest-asserted
    // identical every round here, and property-tested under every churn
    // shape in tests/incremental_equivalence.rs.
    let incr_n: usize = if smoke { 200_000 } else { 1_000_000 };
    let incr_rounds: usize = if smoke { 6 } else { 10 };
    let churn_per_round = (incr_n / 100).max(1); // 1% of the fleet
    let mut incr_rng = Rng::new(0x1DE8);
    let class_costs: Vec<CostFn> = (0..K)
        .map(|_| CostFn::Quadratic {
            fixed: incr_rng.range_f64(0.0, 1.0),
            a: incr_rng.range_f64(0.005, 0.1),
            b: incr_rng.range_f64(0.5, 3.0),
        })
        .collect();
    let mut incr_uppers: Vec<usize> = vec![8; incr_n];
    let sig = |uppers: &[usize], d: usize| -> (CostFn, usize, usize) {
        (class_costs[d % K].clone(), 0, uppers[d])
    };
    let incr_selected: Vec<usize> = (0..incr_n).collect();
    let incr_params =
        RoundParams { tasks: 2 * incr_n, min_tasks: 0, max_share: 1.0 };
    let mut ix = FleetIndex::build(incr_n, |d| sig(&incr_uppers, d));
    let mut incr_time = Duration::ZERO;
    let mut rebuild_time = Duration::ZERO;
    for _ in 0..incr_rounds {
        // Recost 1% of the fleet (battery-style upper-limit moves), then
        // build the round instance both ways over identical signatures.
        let dirty: Vec<usize> = (0..churn_per_round)
            .map(|_| incr_rng.index(incr_n))
            .collect();
        for &d in &dirty {
            incr_uppers[d] = 1 + incr_rng.index(8);
        }
        let t0 = Instant::now();
        for &d in &dirty {
            ix.mark(d);
        }
        ix.apply(|d| sig(&incr_uppers, d));
        let mut relaxed = false;
        let (derived, derived_t) = ix
            .derive(&incr_selected, &incr_params, &mut relaxed)
            .unwrap()
            .expect("fleet never exhausts");
        incr_time += t0.elapsed();

        let t1 = Instant::now();
        let mut relaxed_scratch = false;
        let (scratch, scratch_t) = from_scratch_round(
            |d| sig(&incr_uppers, d),
            &incr_selected,
            &incr_params,
            &mut relaxed_scratch,
        )
        .unwrap()
        .expect("fleet never exhausts");
        rebuild_time += t1.elapsed();
        assert_eq!(
            derived.digest(),
            scratch.digest(),
            "incremental build must be bit-identical to the rebuild"
        );
        assert_eq!(derived_t, scratch_t);
        assert_eq!(relaxed, relaxed_scratch);
    }
    let incr_speedup =
        rebuild_time.as_secs_f64() / incr_time.as_secs_f64().max(1e-9);
    let mut incr_table = Table::new(
        &format!(
            "INCREMENTAL REBUILD: persistent index vs from-scratch round \
             builds (n = {incr_n}, {incr_rounds} rounds, 1% churn)"
        ),
        &["mode", "total", "per round", "speedup"],
    );
    incr_table.rows_str(vec![
        "rebuild".into(),
        fmt_duration(rebuild_time.as_secs_f64()),
        fmt_duration(rebuild_time.as_secs_f64() / incr_rounds as f64),
        "1.0x".into(),
    ]);
    incr_table.rows_str(vec![
        "incremental".into(),
        fmt_duration(incr_time.as_secs_f64()),
        fmt_duration(incr_time.as_secs_f64() / incr_rounds as f64),
        format!("{incr_speedup:.1}x"),
    ]);
    incr_table.print();

    // ---- pareto front: class-level ε-constraint vs flat per-τ DP ---------
    //
    // The deadline work reuses the class machinery: every candidate
    // makespan cap is folded through `capped_fleet` (per-class binary
    // search + the shared round transform) and the *capped* instance is
    // auto-dispatched to its Table-2 marginal algorithm on k classes.
    // The baseline is what the ε-constraint method costs without class
    // dedup and dispatch: re-cap all n devices and run the general
    // (MC)²MKP DP at every τ. Optimal energies must agree at every τ;
    // both legs are single-thread CPU work, so the ≥ 5× gate holds on
    // smoke and full alike.
    let (par_n, par_t, par_k): (usize, usize, usize) =
        if smoke { (60, 60, 6) } else { (200, 150, 10) };
    let mut par_rng = Rng::new(0x9A12);
    let par_class_costs: Vec<CostFn> = (0..par_k)
        .map(|_| CostFn::Affine {
            fixed: par_rng.range_f64(0.0, 1.0),
            per_task: par_rng.range_f64(0.5, 3.0),
        })
        .collect();
    let par_class_speed: Vec<f64> =
        (0..par_k).map(|_| par_rng.range_f64(0.2, 2.0)).collect();
    let par_costs: Vec<CostFn> =
        (0..par_n).map(|d| par_class_costs[d % par_k].clone()).collect();
    let par_times: Vec<TimeModel> = (0..par_n)
        .map(|d| TimeModel::affine(par_class_speed[d % par_k], 1.0))
        .collect();
    let par_upper = 8usize.min(par_t);
    let par_flat = Instance::new(
        par_t,
        vec![0; par_n],
        vec![par_upper; par_n],
        par_costs.clone(),
    )
    .expect("pareto bench fleet valid");
    let par_bi =
        BiFleet::from_flat(&par_flat, &par_times).expect("class-consistent models");
    let par_registry = SolverRegistry::with_defaults(7);
    let par_taus = par_bi.candidate_makespans();
    let flat_point = |tau: f64| -> Option<f64> {
        let mut caps = Vec::with_capacity(par_n);
        let mut room = 0usize;
        for d in 0..par_n {
            let u = par_times[d].max_tasks_within(tau, 0, par_upper)?;
            room += u;
            caps.push(u);
        }
        if room < par_t {
            return None;
        }
        let capped =
            Instance::new(par_t, vec![0; par_n], caps, par_costs.clone()).ok()?;
        let sched = mc2mkp::solve(&capped).ok()?;
        Some(validate::total_cost(&par_flat, &sched))
    };
    let par_cfg = BenchConfig { warmup: 1, iters: 5, min_time_s: 0.005 };
    let m_par_class = bench("pareto_class", &par_cfg, || {
        par_bi.pareto_front(&par_registry, "auto").unwrap()
    });
    let m_par_flat = bench("pareto_flat", &par_cfg, || {
        par_taus.iter().map(|&tau| flat_point(tau)).collect::<Vec<_>>()
    });
    // Per-τ parity: the class path's optimum must match the flat DP's.
    for &tau in &par_taus {
        let class_p = par_bi.solve_constrained(&par_registry, "auto", tau).unwrap();
        match (class_p, flat_point(tau)) {
            (None, None) => {}
            (Some(p), Some(e)) => assert!(
                (p.energy - e).abs() < 1e-6,
                "pareto parity broke at τ={tau}: class {} vs flat {e}",
                p.energy
            ),
            (c, f) => panic!(
                "pareto feasibility parity broke at τ={tau} \
                 (class: {}, flat: {})",
                c.is_some(),
                f.is_some()
            ),
        }
    }
    let par_front = par_bi.pareto_front(&par_registry, "auto").unwrap();
    let par_speedup = m_par_flat.median() / m_par_class.median().max(1e-12);
    let mut par_table = Table::new(
        &format!(
            "PARETO FRONT: class-level ε-constraint vs flat per-τ DP \
             (n = {par_n}, k = {par_k}, T = {par_t}, {} candidate τ)",
            par_taus.len()
        ),
        &["mode", "front points", "time", "speedup"],
    );
    par_table.rows_str(vec![
        "flat DP".into(),
        "—".into(),
        fmt_duration(m_par_flat.median()),
        "1.0x".into(),
    ]);
    par_table.rows_str(vec![
        "class + dispatch".into(),
        par_front.len().to_string(),
        fmt_duration(m_par_class.median()),
        format!("{par_speedup:.1}x"),
    ]);
    par_table.print();

    // ---- networked service: the round loop served over the wire ----------
    //
    // The same stored campaign twice: in-process SimBackend reference vs
    // the loopback service driving a simulated client fleet (rendezvous,
    // heartbeats, slice fetches, reports, injected post-report churn).
    // The two journals must carry the same campaign digest — the
    // tentpole equivalence at fleet scale. The slice-frame bound is the
    // wire-cost claim: one class cost + four scalars per scheduled
    // device, so the largest frame is constant in fleet size.
    let svc_n: usize = if smoke { 100_000 } else { 1_000_000 };
    let svc_rounds: usize = 3;
    let svc_seed: u64 = 0x5EC5;
    let svc_fleet = || -> Vec<ManagedDevice> {
        let mut rng = Rng::new(0xC1A55);
        let class_costs: Vec<CostFn> = (0..K)
            .map(|_| CostFn::Quadratic {
                fixed: rng.range_f64(0.0, 1.0),
                a: rng.range_f64(0.005, 0.1),
                b: rng.range_f64(0.5, 3.0),
            })
            .collect();
        (0..svc_n)
            .map(|i| {
                ManagedDevice::abstract_resource(
                    i,
                    class_costs[i % K].clone(),
                    0,
                    8,
                )
            })
            .collect()
    };
    let svc_cfg = CoordinatorConfig {
        rounds: svc_rounds,
        tasks_per_round: 2_000,
        algo: "marin".into(),
        participation: 1.0,
        max_share: 1.0,
        seed: svc_seed,
        ..CoordinatorConfig::default()
    };
    let service = |churn: u32, miss: u32| {
        loopback_service(
            ServiceConfig::default(),
            SimClientsConfig {
                seed: svc_seed,
                churn_permille: churn,
                miss_permille: miss,
                ..SimClientsConfig::default()
            },
            (0..svc_n).collect(),
        )
    };
    let svc_tmp = std::env::temp_dir().join("fedzero_bench_service");
    let (ref_wall, ref_entries, _) = run_stored_campaign(
        &svc_tmp.join("reference"),
        &svc_cfg,
        svc_fleet(),
        SimBackend::new(),
    );
    let (svc_wall, svc_entries, svc_coord) = run_stored_campaign(
        &svc_tmp.join("loopback"),
        &svc_cfg,
        svc_fleet(),
        service(250, 0),
    );
    assert_eq!(
        campaign_digest(&ref_entries),
        campaign_digest(&svc_entries),
        "loopback campaign must journal the in-process reference bits"
    );
    let svc_rejoins = svc_coord.backend().stats().counter("svc_rejoins");
    assert!(svc_rejoins > 0, "churn must actually fire at fleet scale");
    let svc_frames = svc_coord.backend().stats().counter("svc_frames");
    let (svc_up, svc_down) = svc_coord.backend().transport().bytes();
    let slice_bytes = svc_coord.backend().max_slice_bytes();
    // O(classes) wire bound: the largest slice frame carries one class
    // cost and four scalars — a fixed byte budget no fleet size can
    // breach (cross-checked against a small fleet in svc::tests).
    const SLICE_BOUND: usize = 512;
    let slice_pass = slice_bytes > 0 && slice_bytes <= SLICE_BOUND;

    // Straggler leg: forced deadline misses make rounds partial; the
    // campaign must still complete every round through the coordinator's
    // existing abort/recosting paths.
    let frag_cfg = CoordinatorConfig { rounds: 2, ..svc_cfg.clone() };
    let (_, frag_entries, frag_coord) = run_stored_campaign(
        &svc_tmp.join("stragglers"),
        &frag_cfg,
        svc_fleet(),
        service(250, 100),
    );
    assert_eq!(
        frag_entries.len(),
        frag_cfg.rounds,
        "straggler campaign must journal every round"
    );
    assert!(
        frag_coord.backend().stats().counter("svc_stragglers") > 0,
        "forced misses must produce stragglers"
    );
    let _ = std::fs::remove_dir_all(&svc_tmp);

    let mut svc_table = Table::new(
        &format!(
            "NETWORKED SERVICE: loopback campaign vs in-process reference \
             (n = {svc_n} clients, {svc_rounds} rounds, k = {K} classes)"
        ),
        &["mode", "wall", "wire frames", "bytes up/down", "max slice"],
    );
    svc_table.rows_str(vec![
        "in-process".into(),
        fmt_duration(ref_wall.as_secs_f64()),
        "—".into(),
        "—".into(),
        "—".into(),
    ]);
    svc_table.rows_str(vec![
        "loopback".into(),
        fmt_duration(svc_wall.as_secs_f64()),
        svc_frames.to_string(),
        format!("{svc_up}/{svc_down}"),
        format!("{slice_bytes} B"),
    ]);
    svc_table.print();

    // ---- machine-readable trajectory (BENCH_fleet_scale.json) ------------
    //
    // Schema-versioned: CI copies this file to the repo-root
    // BENCH_fleet_scale.json snapshot, so committed trajectories must
    // state which shape they carry. Bump SCHEMA_VERSION whenever a field
    // is added, removed, or re-meant.
    const SCHEMA_VERSION: usize = 5;
    let solve_gate = if smoke { 2.0 } else { 10.0 };
    let build_gate = 3.0f64;
    let build_pass = build_speedup >= build_gate;
    // The incremental ratio compares two single-thread CPU legs over
    // identical signatures, so it is enforced on smoke and full alike.
    let incr_gate = 5.0f64;
    let incr_pass = incr_speedup >= incr_gate;
    // The pipeline floor is 1.5× on the full sweep; smoke keeps a looser
    // 1.2× tripwire (same reasoning as the solve gate: what CI must catch
    // is the pipeline silently not overlapping, which reads ~1.0×, far
    // below any noise band on a sleep-dominated measurement).
    let pipe_gate = if smoke { 1.2 } else { 1.5 };
    let pipe_pass = pipe_speedup >= pipe_gate;
    // Class-vs-flat front construction is pure dedup + dispatch leverage
    // on two single-thread legs — enforced on smoke and full alike.
    let par_gate = 5.0f64;
    let par_pass = par_speedup >= par_gate;
    let report = Json::obj(vec![
        ("bench", Json::Str("fleet_scale".into())),
        ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
        ("smoke", Json::Bool(smoke)),
        ("solve", Json::Arr(solve_rows)),
        (
            "build",
            Json::obj(vec![
                ("n", Json::Num(build_n as f64)),
                ("t", Json::Num(build_t as f64)),
                ("classes", Json::Num(build_fleet.n_classes() as f64)),
                ("shards", Json::Num(shards as f64)),
                ("workers", Json::Num(workers as f64)),
                ("single_s", Json::Num(m_single.median())),
                ("sharded_s", Json::Num(m_sharded.median())),
                ("speedup", Json::Num(build_speedup)),
            ]),
        ),
        (
            "pipeline",
            Json::obj(vec![
                ("n", Json::Num(pipe_n as f64)),
                ("rounds", Json::Num(pipe_rounds as f64)),
                ("train_delay_s", Json::Num(train_delay.as_secs_f64())),
                ("serial_s", Json::Num(serial_wall.as_secs_f64())),
                ("pipelined_s", Json::Num(piped_wall.as_secs_f64())),
                ("speedup", Json::Num(pipe_speedup)),
                ("speculation_hits", Json::Num(pipe_hits as f64)),
            ]),
        ),
        (
            "incremental",
            Json::obj(vec![
                ("n", Json::Num(incr_n as f64)),
                ("classes", Json::Num(K as f64)),
                ("churn_pct", Json::Num(1.0)),
                ("rounds", Json::Num(incr_rounds as f64)),
                ("incremental_s", Json::Num(incr_time.as_secs_f64())),
                ("rebuild_s", Json::Num(rebuild_time.as_secs_f64())),
                ("speedup", Json::Num(incr_speedup)),
            ]),
        ),
        (
            "pareto",
            Json::obj(vec![
                ("n", Json::Num(par_n as f64)),
                ("t", Json::Num(par_t as f64)),
                ("classes", Json::Num(par_k as f64)),
                ("taus", Json::Num(par_taus.len() as f64)),
                ("front_points", Json::Num(par_front.len() as f64)),
                ("class_s", Json::Num(m_par_class.median())),
                ("flat_s", Json::Num(m_par_flat.median())),
                ("speedup", Json::Num(par_speedup)),
            ]),
        ),
        (
            "service",
            Json::obj(vec![
                ("n", Json::Num(svc_n as f64)),
                ("rounds", Json::Num(svc_rounds as f64)),
                ("classes", Json::Num(K as f64)),
                ("churn_permille", Json::Num(250.0)),
                ("reference_s", Json::Num(ref_wall.as_secs_f64())),
                ("loopback_s", Json::Num(svc_wall.as_secs_f64())),
                ("frames", Json::Num(svc_frames as f64)),
                ("bytes_up", Json::Num(svc_up as f64)),
                ("bytes_down", Json::Num(svc_down as f64)),
                ("rejoins", Json::Num(svc_rejoins as f64)),
                ("max_slice_bytes", Json::Num(slice_bytes as f64)),
                ("digest_match", Json::Bool(true)),
            ]),
        ),
        (
            "gates",
            Json::obj(vec![
                ("solve_worst_speedup", Json::Num(worst_marginal_speedup)),
                ("solve_gate", Json::Num(solve_gate)),
                ("build_gate", Json::Num(build_gate)),
                ("build_gate_enforced", Json::Bool(!smoke)),
                ("build_pass", Json::Bool(build_pass)),
                ("pipeline_gate", Json::Num(pipe_gate)),
                ("pipeline_pass", Json::Bool(pipe_pass)),
                ("incremental_gate", Json::Num(incr_gate)),
                ("incremental_pass", Json::Bool(incr_pass)),
                ("pareto_gate", Json::Num(par_gate)),
                ("pareto_pass", Json::Bool(par_pass)),
                ("service_slice_bound", Json::Num(SLICE_BOUND as f64)),
                ("service_slice_bytes", Json::Num(slice_bytes as f64)),
                ("service_pass", Json::Bool(slice_pass)),
            ]),
        ),
    ]);
    let mut payload = report.to_string();
    payload.push('\n');
    std::fs::write("BENCH_fleet_scale.json", payload)
        .expect("write BENCH_fleet_scale.json");
    println!("wrote BENCH_fleet_scale.json (schema v{SCHEMA_VERSION})");

    // Every gated ratio is ENFORCED — a regression below its floor exits
    // non-zero so CI fails instead of merely printing the miss. The full
    // sweep enforces the acceptance bars (solve ≥ 10×, build ≥ 3×,
    // pipeline ≥ 1.5×); smoke enforces the looser tripwires above, except
    // the build ratio, which is recorded but not asserted (CI smoke
    // runners expose too few cores for an honest parallelism gate).
    println!(
        "acceptance: every marginal algorithm ≥ {solve_gate}x — worst observed {:.0}x ({})",
        worst_marginal_speedup,
        if worst_marginal_speedup >= solve_gate { "PASS" } else { "FAIL" }
    );
    println!(
        "acceptance: sharded build ≥ {build_gate}x single-thread at n = {build_n} — \
         observed {build_speedup:.1}x ({})",
        if build_pass {
            "PASS"
        } else if smoke {
            "INFO (smoke: not enforced)"
        } else {
            "FAIL"
        }
    );
    println!(
        "acceptance: pipelined rounds ≥ {pipe_gate}x serial at n = {pipe_n} — \
         observed {pipe_speedup:.2}x ({})",
        if pipe_pass { "PASS" } else { "FAIL" }
    );
    println!(
        "acceptance: incremental re-derivation ≥ {incr_gate}x rebuild at \
         n = {incr_n}, 1% churn — observed {incr_speedup:.1}x ({})",
        if incr_pass { "PASS" } else { "FAIL" }
    );
    println!(
        "acceptance: class-level front construction ≥ {par_gate}x flat per-τ \
         DP at n = {par_n} — observed {par_speedup:.1}x ({})",
        if par_pass { "PASS" } else { "FAIL" }
    );
    println!(
        "acceptance: slice frames ≤ {SLICE_BOUND} B at n = {svc_n} clients \
         (O(classes) wire payload) — observed {slice_bytes} B ({})",
        if slice_pass { "PASS" } else { "FAIL" }
    );
    assert!(
        worst_marginal_speedup >= solve_gate,
        "class-path speedup regressed below {solve_gate}x"
    );
    assert!(
        smoke || build_pass,
        "sharded instance build regressed below {build_gate}x single-thread"
    );
    assert!(
        pipe_pass,
        "pipelined round throughput regressed below {pipe_gate}x serial"
    );
    assert!(
        incr_pass,
        "incremental round re-derivation regressed below {incr_gate}x the \
         from-scratch rebuild"
    );
    assert!(
        par_pass,
        "class-level Pareto-front construction regressed below {par_gate}x \
         the flat per-τ DP baseline"
    );
    assert!(
        slice_pass,
        "schedule-slice frame grew past {SLICE_BOUND} bytes — the O(classes) \
         wire-payload bound broke"
    );
}
