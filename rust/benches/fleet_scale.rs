//! FLEET — flat per-device vs class-deduplicated solve times.
//!
//! A fleet of `n` devices in `k = 100` classes (multiplicity `n/k` each)
//! is solved twice per marginal algorithm: through the legacy flat path
//! (`O(n)`-ish) and through the class-aware `solve_fleet` path
//! (`O(k)`-ish). The acceptance bar for the redesign is a **≥ 10×**
//! speedup at `n = 10⁵` on at least one marginal algorithm; in practice
//! MarIn/MarCo/MarDecUn all clear it by orders of magnitude.
//!
//! The (MC)²MKP DP is included at the smallest size as a *parity* row:
//! arbitrary costs admit no intra-class shortcut, so the class DP matches
//! the flat DP's arithmetic (the win there is memory — rolling f64 rows,
//! only `u32` backtrack tables at `O(n·T)`), and its speedup is expected
//! to be ~1×.
//!
//! `FEDZERO_BENCH_SMOKE=1` shrinks the sweep to `n = 10³` with quick
//! timing — the CI regression gate.

use fedzero::benchkit::{bench, BenchConfig};
use fedzero::sched::costs::CostFn;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::{marco, mardecun, marin, mc2mkp};
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_duration, Table};

const K: usize = 100;

fn build(algo: &str, n: usize, t: usize) -> (FleetInstance, Instance) {
    let mut rng = Rng::new((n as u64).wrapping_mul(0xF1EE7) ^ algo.len() as u64);
    let mut b = FleetInstance::builder().tasks(t);
    for _ in 0..K {
        let (cost, upper) = match algo {
            "marin" => (
                CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 1.0),
                    a: rng.range_f64(0.005, 0.1),
                    b: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            "marco" => (
                CostFn::Affine {
                    fixed: rng.range_f64(0.0, 1.0),
                    per_task: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            "mardecun" => (
                CostFn::PowerLaw {
                    fixed: 0.0,
                    scale: rng.range_f64(0.5, 3.0),
                    exponent: rng.range_f64(0.3, 0.9),
                },
                t,
            ),
            "mc2mkp" => (
                CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 1.0),
                    a: rng.range_f64(0.005, 0.1),
                    b: rng.range_f64(0.5, 3.0),
                },
                8,
            ),
            other => panic!("unknown algo {other}"),
        };
        b = b.device_class(cost, 0, upper, n / K);
    }
    let fleet = b.build().expect("bench fleet valid");
    let flat = fleet.to_flat();
    (fleet, flat)
}

fn main() {
    let smoke = std::env::var("FEDZERO_BENCH_SMOKE").is_ok();
    let sizes: &[usize] = if smoke {
        &[1_000]
    } else {
        &[1_000, 10_000, 100_000]
    };
    // Smoke still batches each sample to ≥ ~0.5 ms (the class path solves
    // in microseconds; unbatched medians would be scheduler-noise).
    let cfg = if smoke {
        BenchConfig { warmup: 1, iters: 9, min_time_s: 0.005 }
    } else {
        BenchConfig { warmup: 1, iters: 7, min_time_s: 0.02 }
    };

    let mut table = Table::new(
        &format!("FLEET SCALE: flat vs class-deduplicated solves (k = {K} classes)"),
        &["algorithm", "n", "T", "flat", "class", "dedup", "speedup"],
    );
    let mut worst_marginal_speedup = f64::INFINITY;

    for &n in sizes {
        let t = 2 * n;
        for algo in ["marin", "marco", "mardecun"] {
            let (fleet, flat) = build(algo, n, t);
            let m_flat = match algo {
                "marin" => bench("flat", &cfg, || marin::solve(&flat).unwrap()),
                "marco" => bench("flat", &cfg, || marco::solve(&flat).unwrap()),
                "mardecun" => {
                    bench("flat", &cfg, || mardecun::solve(&flat).unwrap())
                }
                _ => unreachable!(),
            };
            let m_class = match algo {
                "marin" => bench("class", &cfg, || marin::solve_fleet(&fleet).unwrap()),
                "marco" => bench("class", &cfg, || marco::solve_fleet(&fleet).unwrap()),
                "mardecun" => {
                    bench("class", &cfg, || mardecun::solve_fleet(&fleet).unwrap())
                }
                _ => unreachable!(),
            };
            // Cost of deduplicating a flat instance from scratch — what a
            // caller pays when it does NOT maintain a FleetInstance.
            let m_dedup = bench("dedup", &cfg, || {
                FleetInstance::from_flat(&flat).unwrap()
            });
            let speedup = m_flat.median() / m_class.median().max(1e-12);
            worst_marginal_speedup = worst_marginal_speedup.min(speedup);
            table.rows_str(vec![
                algo.to_string(),
                n.to_string(),
                t.to_string(),
                fmt_duration(m_flat.median()),
                fmt_duration(m_class.median()),
                fmt_duration(m_dedup.median()),
                format!("{speedup:.0}x"),
            ]);
        }
    }

    // Parity row: the DP has no intra-class shortcut for arbitrary costs.
    {
        let n = sizes[0];
        let t = 2 * n;
        let (fleet, flat) = build("mc2mkp", n, t);
        let m_flat = bench("flat", &cfg, || mc2mkp::solve(&flat).unwrap());
        let m_class = bench("class", &cfg, || mc2mkp::solve_fleet(&fleet).unwrap());
        let speedup = m_flat.median() / m_class.median().max(1e-12);
        table.rows_str(vec![
            "mc2mkp (parity)".to_string(),
            n.to_string(),
            t.to_string(),
            fmt_duration(m_flat.median()),
            fmt_duration(m_class.median()),
            "—".to_string(),
            format!("{speedup:.1}x"),
        ]);
    }

    table.print();
    // Full sweep enforces the acceptance bar; smoke (n = 10³, batched
    // timing) enforces a looser gate that still catches the failure mode
    // CI exists for — a class-aware solver silently regressing to the
    // flat path shows up as ~1x, far below any plausible noise band.
    let gate = if smoke { 2.0 } else { 10.0 };
    println!(
        "acceptance: every marginal algorithm ≥ {gate}x — worst observed {:.0}x ({})",
        worst_marginal_speedup,
        if worst_marginal_speedup >= gate { "PASS" } else { "FAIL" }
    );
    assert!(
        worst_marginal_speedup >= gate,
        "class-path speedup regressed below {gate}x"
    );
}
