//! Integration tests for dynamic fleet behaviour (availability churn,
//! cost drift, dropout) end-to-end through the FL server.
//!
//! All tests are `#[ignore]`d with an explicit reason (see
//! fl_integration.rs): they need PJRT artifacts plus a real xla backend,
//! which the offline build does not have. The sim-backend equivalents in
//! tests/coordinator_roundloop.rs and tests/store_recovery.rs cover the
//! same dynamics paths without artifacts.

use std::path::Path;

use fedzero::config::TrainConfig;
use fedzero::coordinator::KnobSet;
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::BehaviorMix;
use fedzero::fl::dynamics::{Availability, CostDrift, Dropout, DynamicsConfig};
use fedzero::fl::Server;

/// Configure dynamics through the shared knob seam (the per-knob
/// `Server` setters were folded into `KnobSet` in the service PR).
fn set_dynamics(server: &mut Server, dynamics: DynamicsConfig) {
    server
        .apply_knobs(KnobSet {
            dynamics: Some(dynamics),
            ..KnobSet::default()
        })
        .unwrap();
}

fn artifacts_present() -> bool {
    let ok = Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("dynamics_integration: artifacts missing, skipping");
    }
    ok
}

fn cfg(rounds: usize) -> TrainConfig {
    TrainConfig {
        rounds,
        devices: 10,
        tasks_per_round: 40,
        model: "mlp".into(),
        seed: 31,
        ..TrainConfig::default()
    }
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn dropout_wastes_energy_but_training_survives() {
    if !artifacts_present() {
        return;
    }
    let mut server =
        Server::new(cfg(8), BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
    set_dynamics(&mut server, DynamicsConfig {
        availability: None,
        drift: None,
        dropout: Some(Dropout { p_fail: 0.4 }),
    });
    server.run().unwrap();
    assert!(server.metrics().counter("dropouts") > 0, "no dropouts sampled");
    // Training still completes and the loss is finite.
    assert!(server.log().final_loss().unwrap().is_finite());
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn churn_produces_empty_and_partial_rounds() {
    if !artifacts_present() {
        return;
    }
    let mut server =
        Server::new(cfg(20), BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
    set_dynamics(&mut server, DynamicsConfig {
        availability: Some(Availability::new(10, 0.05, 0.6)), // mostly offline
        drift: None,
        dropout: None,
    });
    server.run().unwrap();
    let rows = server.log().rows();
    assert_eq!(rows.len(), 20);
    // With heavy churn some rounds should have few participants.
    let min_participants = rows.iter().map(|r| r.participants).min().unwrap();
    assert!(min_participants <= 3, "churn had no visible effect");
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn drift_changes_round_energy_over_time() {
    if !artifacts_present() {
        return;
    }
    let run_total = |drift: Option<CostDrift>| -> Vec<f64> {
        let mut server =
            Server::new(cfg(12), BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
        set_dynamics(&mut server, DynamicsConfig {
            availability: None,
            drift,
            dropout: None,
        });
        server.run().unwrap();
        server.log().rows().iter().map(|r| r.energy_j).collect()
    };
    let stable = run_total(None);
    let drifted = run_total(Some(CostDrift::new(10, 0.3)));
    // Without drift the round energy is constant (same fleet, same T);
    // with drift it varies.
    let var = |v: &[f64]| {
        let m = v.iter().sum::<f64>() / v.len() as f64;
        v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64
    };
    assert!(var(&stable) < 1e-6, "stable energy should not vary: {stable:?}");
    assert!(var(&drifted) > 1e-6, "drift had no effect: {drifted:?}");
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn mobile_preset_runs() {
    if !artifacts_present() {
        return;
    }
    let mut server = Server::new(cfg(6), BehaviorMix::Mixed).unwrap();
    set_dynamics(&mut server, DynamicsConfig::mobile(10));
    server.run().unwrap();
    assert_eq!(server.log().rows().len(), 6);
}
