//! Binary-level tests of the durable-campaign CLI: `train --backend sim
//! --store`, crash simulation (journal truncated to a prefix + snapshot
//! removed — exactly the on-disk state a SIGKILL leaves, since the
//! journal is append-only and snapshots replace atomically), `resume`,
//! and `replay` digest equality between the clean and recovered runs.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn fedzero(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_fedzero"))
        .args(args)
        .output()
        .expect("failed to spawn the fedzero binary")
}

fn stdout_ok(args: &[&str]) -> String {
    let out = fedzero(args);
    assert!(
        out.status.success(),
        "fedzero {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fedzero_cli_store").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn train_args(dir: &Path) -> Vec<String> {
    let mut args: Vec<String> = [
        "train",
        "--backend",
        "sim",
        "--store",
        dir.to_str().unwrap(),
        "--rounds",
        "30",
        "--devices",
        "12",
        "--tasks",
        "24",
        "--algo",
        "auto",
        "--seed",
        "11",
        "--dynamics",
        "mobile",
        "--snapshot-every",
        "10",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    args.push("--out".into());
    args.push(dir.join("run.csv").to_string_lossy().into_owned());
    args
}

fn campaign_line(replay_output: &str) -> String {
    replay_output
        .lines()
        .find(|l| l.starts_with("campaign digest"))
        .unwrap_or_else(|| panic!("no campaign digest line in: {replay_output}"))
        .to_string()
}

/// Truncate the journal to its first `keep` lines and drop the periodic
/// snapshot — the on-disk state of a campaign killed after round `keep`
/// with its last snapshot lost.
fn simulate_crash_at(dir: &Path, keep: usize) {
    let journal = dir.join("journal.jsonl");
    let text = std::fs::read_to_string(&journal).unwrap();
    let prefix: String =
        text.lines().take(keep).map(|l| format!("{l}\n")).collect();
    std::fs::write(&journal, prefix).unwrap();
    let _ = std::fs::remove_file(dir.join("snapshot.json"));
}

#[test]
fn train_resume_replay_roundtrip_is_bit_for_bit() {
    let clean = scratch("clean");
    let crash = scratch("crash");
    let args: Vec<String> = train_args(&clean);
    let argrefs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let out = stdout_ok(&argrefs);
    assert!(out.contains("campaign store:"), "{out}");

    // Identical campaign into a second store, then "crash" it at round 13.
    let args: Vec<String> = train_args(&crash);
    let argrefs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    stdout_ok(&argrefs);
    simulate_crash_at(&crash, 13);

    let resume_out = stdout_ok(&["resume", crash.to_str().unwrap()]);
    assert!(resume_out.contains("resuming"), "{resume_out}");
    assert!(resume_out.contains("done:"), "{resume_out}");

    // The streamed --out sink was re-attached from meta.json: both runs
    // end with a complete CSV (header + 30 rows), crash or not.
    for dir in [&clean, &crash] {
        let csv = std::fs::read_to_string(dir.join("run.csv")).unwrap();
        assert_eq!(csv.lines().count(), 31, "incomplete CSV in {dir:?}");
        assert!(csv.starts_with("round,policy,loss"));
    }

    // Replay both campaigns: the audit must pass and the deterministic
    // campaign digests (timings excluded) must be identical.
    let clean_replay = stdout_ok(&["replay", clean.to_str().unwrap()]);
    let crash_replay = stdout_ok(&["replay", crash.to_str().unwrap()]);
    assert!(clean_replay.contains("replayed 30 rounds"), "{clean_replay}");
    assert!(crash_replay.contains("replayed 30 rounds"), "{crash_replay}");
    assert_eq!(campaign_line(&clean_replay), campaign_line(&crash_replay));

    // Resuming a complete campaign is a verified no-op.
    let again = stdout_ok(&["resume", crash.to_str().unwrap()]);
    assert!(again.contains("already complete"), "{again}");

    let _ = std::fs::remove_dir_all(&clean);
    let _ = std::fs::remove_dir_all(&crash);
}

#[test]
fn traced_campaign_digest_matches_untraced_and_stats_renders() {
    let plain = scratch("trace_plain");
    let traced = scratch("trace_traced");
    let trace_file = std::env::temp_dir()
        .join("fedzero_cli_store")
        .join("campaign.trace.jsonl");
    let _ = std::fs::remove_file(&trace_file);

    let args: Vec<String> = train_args(&plain);
    let argrefs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    stdout_ok(&argrefs);

    let mut args: Vec<String> = train_args(&traced);
    args.push("--trace".into());
    args.push(trace_file.to_string_lossy().into_owned());
    let argrefs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    stdout_ok(&argrefs);

    // Crash the traced campaign and resume WITHOUT --trace: the path is
    // read back from the store meta and re-attached in append mode.
    simulate_crash_at(&traced, 13);
    stdout_ok(&["resume", traced.to_str().unwrap()]);

    // Tracing must not perturb the campaign digest — even across a
    // crash/resume cycle.
    let plain_replay = stdout_ok(&["replay", plain.to_str().unwrap()]);
    let traced_replay = stdout_ok(&["replay", traced.to_str().unwrap()]);
    assert_eq!(campaign_line(&plain_replay), campaign_line(&traced_replay));

    // The trace is valid JSONL with balanced duration spans (the resumed
    // process appended to the same file).
    let text = std::fs::read_to_string(&trace_file).unwrap();
    assert!(!text.is_empty());
    let mut open = 0i64;
    for line in text.lines() {
        let v = fedzero::util::json::Json::parse(line).unwrap();
        match v.req("ph").unwrap().as_str().unwrap() {
            "B" => open += 1,
            "E" => open -= 1,
            "i" => {}
            other => panic!("unexpected phase {other}"),
        }
        assert!(open >= 0, "E before B");
    }
    assert_eq!(open, 0, "unbalanced spans");

    // The dashboard renders from the store alone.
    let stats = stdout_ok(&["stats", traced.to_str().unwrap(), "--expose"]);
    assert!(stats.contains("30 of 30 rounds journaled"), "{stats}");
    assert!(stats.contains("per-solver usage"), "{stats}");
    assert!(stats.contains("energy concentration"), "{stats}");
    assert!(stats.contains("fedzero_rounds 30"), "{stats}");

    let _ = std::fs::remove_dir_all(&plain);
    let _ = std::fs::remove_dir_all(&traced);
    let _ = std::fs::remove_file(&trace_file);
}

#[test]
fn store_refuses_silent_overwrite_and_fl_backend() {
    let dir = scratch("overwrite");
    let args: Vec<String> = train_args(&dir);
    let argrefs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    stdout_ok(&argrefs);

    // A second `train --store` into the same directory must refuse.
    let out = fedzero(&argrefs);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("resume"), "{err}");

    // And --store with the PJRT backend is rejected up front.
    let out = fedzero(&["train", "--store", "/tmp/nope-fl-store"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--backend sim"), "{err}");

    let _ = std::fs::remove_dir_all(&dir);
}
