//! Crash-recovery property tests for the durable coordinator store:
//! kill a campaign after round `r` (several `r`, several failure modes),
//! restore from the store, run the remaining rounds, and require the
//! journaled campaign to be **bit-for-bit identical** to an uninterrupted
//! run — schedules (via instance+schedule digests), per-round energy, RNG
//! states — for every registered solver on a small dynamic fleet.

use std::path::{Path, PathBuf};

use fedzero::coordinator::{
    Coordinator, CoordinatorConfig, ManagedDevice, SimBackend,
};
use fedzero::energy::battery::Battery;
use fedzero::energy::power::{Behavior, PowerModel};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::sched::costs::CostFn;
use fedzero::store::journal::{campaign_digest, JournalEntry};
use fedzero::store::{get, snapshot as snap, CampaignStore};
use fedzero::util::json::Json;

const ROUNDS: usize = 12;
const SNAPSHOT_EVERY: usize = 4;

/// Fresh scratch directory under the system tempdir.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fedzero_store_recovery")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A 7-device fleet exercising every state the snapshot must carry:
/// duplicated specs (multiplicity classes), a lower limit, tabulated /
/// power-law / quadratic costs, and one battery-powered device whose
/// drain re-costs later rounds.
fn fleet() -> Vec<ManagedDevice> {
    let affine = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
    let quad = CostFn::Quadratic { fixed: 0.5, a: 0.25, b: 0.5 };
    let table = CostFn::from_table(&[
        (0, 0.0),
        (1, 1.5),
        (2, 2.5),
        (3, 4.5),
        (4, 5.0),
    ]);
    let sqrtish = CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.6 };
    let power = PowerModel {
        idle_w: 0.1,
        busy_w: 2.0,
        batch_latency_s: 0.5,
        behavior: Behavior::Linear,
        curvature: 0.0,
    }; // 1 J per task
    vec![
        ManagedDevice::abstract_resource(0, affine.clone(), 0, 4),
        ManagedDevice::abstract_resource(1, affine, 0, 4),
        ManagedDevice::abstract_resource(2, quad, 0, 5),
        ManagedDevice::abstract_resource(3, table, 1, 4),
        ManagedDevice::abstract_resource(4, sqrtish.clone(), 0, 6),
        ManagedDevice::abstract_resource(5, sqrtish, 0, 6),
        ManagedDevice {
            id: 6,
            cost: power.cost_fn(),
            lower: 0,
            data_cap: 8,
            battery: Some(Battery {
                capacity_wh: 60.0 / 3600.0, // 60 J total
                level: 1.0,
                round_budget_frac: 0.4,
            }),
            power: Some(power),
            drift: 1.0,
            deadline_cap: usize::MAX,
        },
    ]
}

fn cfg_for(solver: &str, seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        rounds: ROUNDS,
        tasks_per_round: 8,
        algo: solver.to_string(),
        participation: 0.8,
        max_share: 1.0,
        seed,
        ..CoordinatorConfig::default()
    }
}

fn new_stored(solver: &str, seed: u64, dir: &Path) -> Coordinator<SimBackend> {
    let cfg = cfg_for(solver, seed);
    let mut c =
        Coordinator::new(cfg.clone(), fleet(), SimBackend::new()).unwrap();
    c.set_dynamics(DynamicsConfig::mobile(7));
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(SNAPSHOT_EVERY as f64)),
        ("cfg", snap::cfg_to_json(&cfg)),
    ]);
    let store = CampaignStore::create(dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
    c
}

/// Drive `upto` rounds. Solvers outside their scenario (e.g. MarDecUn on
/// a limited fleet) abort every round — those aborts must persist and
/// replay too, so errors are tolerated here.
fn drive(c: &mut Coordinator<SimBackend>, upto: usize) {
    while c.rounds_run() < upto {
        let _ = c.round_stored();
    }
}

fn run_full(solver: &str, seed: u64, dir: &Path) -> Vec<JournalEntry> {
    let mut c = new_stored(solver, seed, dir);
    drive(&mut c, ROUNDS);
    CampaignStore::read(dir).unwrap().entries
}

fn resume_to_end(dir: &Path) -> Vec<JournalEntry> {
    let (store, contents) = CampaignStore::resume(dir).unwrap();
    let cfg = snap::cfg_from_json(get(&contents.meta, "cfg").unwrap()).unwrap();
    let mut c = Coordinator::restore(
        cfg,
        &contents.snapshot,
        &contents.entries,
        SimBackend::new(),
        None,
    )
    .unwrap();
    c.attach_store(store).unwrap();
    drive(&mut c, ROUNDS);
    CampaignStore::read(dir).unwrap().entries
}

fn assert_campaigns_equal(solver: &str, r: usize, a: &[JournalEntry], b: &[JournalEntry]) {
    assert_eq!(a.len(), ROUNDS, "{solver}: clean run length");
    assert_eq!(b.len(), ROUNDS, "{solver}: resumed run length (crash at {r})");
    for (ea, eb) in a.iter().zip(b) {
        let ctx = format!("{solver}, crash at {r}, round {}", ea.round);
        assert_eq!(ea.round, eb.round, "{ctx}: round index");
        assert_eq!(ea.solver, eb.solver, "{ctx}: effective solver");
        assert_eq!(ea.digest, eb.digest, "{ctx}: instance/schedule digest");
        assert_eq!(ea.rng_after, eb.rng_after, "{ctx}: RNG state");
        assert_eq!(
            ea.row.energy_j.to_bits(),
            eb.row.energy_j.to_bits(),
            "{ctx}: energy"
        );
        assert!(
            ea.row.loss.to_bits() == eb.row.loss.to_bits()
                || (ea.row.loss.is_nan() && eb.row.loss.is_nan()),
            "{ctx}: loss {} vs {}",
            ea.row.loss,
            eb.row.loss
        );
        assert_eq!(ea.row.participants, eb.row.participants, "{ctx}");
        assert_eq!(ea.row.tasks, eb.row.tasks, "{ctx}");
    }
    assert_eq!(
        campaign_digest(a),
        campaign_digest(b),
        "{solver}: campaign digest (crash at {r})"
    );
}

/// The core property: for every registered solver, killing after round
/// `r` and resuming reproduces the uninterrupted campaign exactly, for
/// several `r` straddling the snapshot cadence.
#[test]
fn kill_and_resume_matches_uninterrupted_run_for_all_solvers() {
    let solvers = [
        "auto",
        "mc2mkp",
        "marin",
        "marco",
        "mardec",
        "mardecun", // scenario-mismatched here: aborts must replay too
        "bruteforce",
        "uniform",
        "random",
        "proportional",
        "greedy",
        "olar",
    ];
    for (si, solver) in solvers.iter().enumerate() {
        let seed = 100 + si as u64;
        let clean_dir = scratch(&format!("{solver}_clean"));
        let clean = run_full(solver, seed, &clean_dir);

        // r = 1 (before the first snapshot), 5 (between snapshots),
        // 9 (after the latest snapshot at 8).
        for r in [1usize, 5, 9] {
            let crash_dir = scratch(&format!("{solver}_crash_{r}"));
            {
                let mut c = new_stored(solver, seed, &crash_dir);
                drive(&mut c, r);
                // Dropping the coordinator mid-campaign IS the crash: the
                // journal is fsync'd per round, nothing else is flushed.
            }
            let resumed = resume_to_end(&crash_dir);
            assert_campaigns_equal(solver, r, &clean, &resumed);
            let _ = std::fs::remove_dir_all(&crash_dir);
        }
        let _ = std::fs::remove_dir_all(&clean_dir);
    }
}

/// A torn trailing journal line (crash mid-append) is discarded and the
/// campaign still resumes to the exact clean-run state.
#[test]
fn torn_journal_line_is_recovered_from() {
    let solver = "auto";
    let seed = 42;
    let clean_dir = scratch("torn_clean");
    let clean = run_full(solver, seed, &clean_dir);

    let crash_dir = scratch("torn_crash");
    {
        let mut c = new_stored(solver, seed, &crash_dir);
        drive(&mut c, 6);
    }
    // Tear the tail: half a JSON object, no newline.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(crash_dir.join("journal.jsonl"))
            .unwrap();
        f.write_all(b"{\"round\":6,\"solver\":\"mar").unwrap();
    }
    let resumed = resume_to_end(&crash_dir);
    assert_campaigns_equal(solver, 6, &clean, &resumed);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
}

/// A corrupt periodic snapshot degrades to replaying from the initial
/// snapshot — never to divergence or failure.
#[test]
fn corrupt_snapshot_falls_back_to_full_replay() {
    let solver = "mc2mkp";
    let seed = 77;
    let clean_dir = scratch("corrupt_clean");
    let clean = run_full(solver, seed, &clean_dir);

    let crash_dir = scratch("corrupt_crash");
    {
        let mut c = new_stored(solver, seed, &crash_dir);
        drive(&mut c, 9); // a periodic snapshot exists (round 8)
    }
    std::fs::write(crash_dir.join("snapshot.json"), b"{not json").unwrap();
    let resumed = resume_to_end(&crash_dir);
    assert_campaigns_equal(solver, 9, &clean, &resumed);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
}

/// `replay` semantics: a full verified re-derivation from the initial
/// snapshot succeeds on an honest journal and fails loudly on a forged
/// one.
#[test]
fn replay_verifies_and_detects_forgery() {
    let solver = "auto";
    let seed = 9;
    let dir = scratch("replay_audit");
    let entries = run_full(solver, seed, &dir);
    let contents = CampaignStore::read(&dir).unwrap();
    let cfg = snap::cfg_from_json(get(&contents.meta, "cfg").unwrap()).unwrap();

    // Honest journal: restore-from-init verifies every round.
    let c = Coordinator::restore(
        cfg.clone(),
        &contents.init_snapshot,
        &contents.entries,
        SimBackend::new(),
        None,
    )
    .unwrap();
    assert_eq!(c.rounds_run(), ROUNDS);

    // Forged journal: tamper with one round's digest.
    let mut forged = entries;
    forged[3].digest ^= 1;
    let err = match Coordinator::restore(
        cfg,
        &contents.init_snapshot,
        &forged,
        SimBackend::new(),
        None,
    ) {
        Ok(_) => panic!("forged journal must not verify"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("replay mismatch"), "{err}");
    assert!(err.contains("round 3"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Streaming + bounded retention: the rounds file holds every row while
/// in-memory retention stays flat — the "memory no longer grows with
/// round count" acceptance criterion.
#[test]
fn stored_campaign_memory_is_bounded_and_rows_stream() {
    let dir = scratch("bounded");
    let cfg = cfg_for("auto", 5);
    let mut c =
        Coordinator::new(cfg.clone(), fleet(), SimBackend::new()).unwrap();
    c.set_log_bound(Some(4));
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(SNAPSHOT_EVERY as f64)),
        ("cfg", snap::cfg_to_json(&cfg)),
    ]);
    let store = CampaignStore::create(&dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
    drive(&mut c, ROUNDS);
    assert_eq!(c.log().total_rows(), ROUNDS);
    assert!(c.log().rows().len() < 8, "log ring must stay bounded");
    assert!(c.ledger().rounds().len() < 8, "ledger ring must stay bounded");
    assert_eq!(c.ledger().rounds_opened(), ROUNDS);
    let rounds_file =
        std::fs::read_to_string(dir.join("rounds.jsonl")).unwrap();
    assert_eq!(rounds_file.lines().count(), ROUNDS);
    let _ = std::fs::remove_dir_all(&dir);
}
