//! Golden tests pinning byte-stable output ordering: journal lines, the
//! `MetricsHub` summary, and the solver registry's applicability text
//! (what `solvers` and `--algo` errors print). The store's journals and
//! the CI recovery diff both depend on these being identical across runs
//! and platforms, so any ordering change must be a conscious one.

use fedzero::coordinator::{
    Coordinator, CoordinatorConfig, DeadlineConfig, IncrementalConfig,
    ManagedDevice, PipelineConfig, SimBackend,
};
use fedzero::metrics::{MetricsHub, RoundLog};
use fedzero::sched::instance::Instance;
use fedzero::sched::solver::SolverRegistry;
use fedzero::store::journal::{campaign_digest, JournalEntry};
use fedzero::store::sink::row_to_json;
use fedzero::store::CampaignStore;
use fedzero::util::json::Json;

#[test]
fn registry_describe_order_is_pinned() {
    // Registration order, each with its Table 2 applicability — the exact
    // text `solvers` and `--algo` errors print. A new solver extends this
    // string; nothing may reorder it.
    let registry = SolverRegistry::with_defaults(0);
    assert_eq!(
        registry.describe().join(" "),
        "auto[arb,inc,con,dec,dec∞] mc2mkp[arb,inc,con,dec,dec∞] \
         marin[inc,con] marco[con] mardecun[dec∞] mardec[con,dec,dec∞] \
         bruteforce[arb,inc,con,dec,dec∞] uniform[—] random[—] \
         proportional[—] greedy[—] olar[—]"
    );
}

#[test]
fn registry_names_order_is_pinned() {
    let registry = SolverRegistry::with_defaults(0);
    assert_eq!(
        registry.names(),
        vec![
            "auto",
            "mc2mkp",
            "marin",
            "marco",
            "mardecun",
            "mardec",
            "bruteforce",
            "uniform",
            "random",
            "proportional",
            "greedy",
            "olar",
        ]
    );
}

#[test]
fn metrics_summary_is_byte_stable() {
    // Counters first (name-sorted), then gauges (name-sorted, 4 decimal
    // places) — insertion order must not leak into the output.
    let mut a = MetricsHub::new();
    a.inc("rounds", 2);
    a.inc("dp_solves", 1);
    a.set("train_loss", 0.5);
    a.set("eval_loss", 0.125);
    assert_eq!(
        a.summary(),
        "dp_solves=1 rounds=2 eval_loss=0.1250 train_loss=0.5000"
    );

    let mut b = MetricsHub::new();
    b.set("eval_loss", 0.125);
    b.inc("dp_solves", 1);
    b.set("train_loss", 0.5);
    b.inc("rounds", 2);
    assert_eq!(a.summary(), b.summary(), "insertion order must not matter");
}

#[test]
fn expose_text_is_byte_stable() {
    // Counters first then gauges, each name-sorted with a `# TYPE` line,
    // names prefixed `fedzero_`, floats through the deterministic Json
    // writer — what the `stats --expose` dashboard prints. Insertion
    // order must not leak into the output.
    let mut a = MetricsHub::new();
    a.set("obs_sched_ns_p95", 250000.0);
    a.inc("rounds", 3);
    a.inc("pipeline_hits", 2);
    a.set("eval_loss", 0.125);
    assert_eq!(
        a.expose_text(),
        "# TYPE fedzero_pipeline_hits counter\nfedzero_pipeline_hits 2\n\
         # TYPE fedzero_rounds counter\nfedzero_rounds 3\n\
         # TYPE fedzero_eval_loss gauge\nfedzero_eval_loss 0.125\n\
         # TYPE fedzero_obs_sched_ns_p95 gauge\nfedzero_obs_sched_ns_p95 250000\n"
    );

    let mut b = MetricsHub::new();
    b.set("eval_loss", 0.125);
    b.inc("pipeline_hits", 2);
    b.set("obs_sched_ns_p95", 250000.0);
    b.inc("rounds", 3);
    assert_eq!(a.expose_text(), b.expose_text(), "insertion order must not matter");
}

#[test]
fn cfg_codec_bytes_are_pinned() {
    // The persisted cfg is campaign identity: `resume`/`replay` rebuild
    // the coordinator from these exact bytes, and the CI recovery diff
    // compares stores byte-for-byte. The toggle-trio unification
    // (on()/off()/From<bool> for pipeline/incremental, From<Option<f64>>
    // for deadline) must not move a single byte of this encoding.
    let off = CoordinatorConfig {
        rounds: 12,
        tasks_per_round: 40,
        algo: "auto".into(),
        participation: 0.5,
        min_tasks: 2,
        max_share: 0.25,
        seed: 0xfeed,
        target_loss: None,
        shards: 1,
        pipeline: PipelineConfig::off(),
        incremental: IncrementalConfig::off(),
        deadline: DeadlineConfig::off(),
    };
    assert_eq!(
        fedzero::store::snapshot::cfg_to_json(&off).to_string(),
        "{\"algo\":\"auto\",\"incremental\":false,\"max_share\":0.25,\
         \"min_tasks\":2,\"participation\":0.5,\"pipeline\":false,\
         \"rounds\":12,\"seed\":\"feed\",\"shards\":1,\"target_loss\":null,\
         \"tasks_per_round\":40}"
    );
    let on = CoordinatorConfig {
        algo: "mc2mkp".into(),
        target_loss: Some(0.125),
        shards: 3,
        pipeline: PipelineConfig::on(),
        incremental: IncrementalConfig::on(),
        deadline: DeadlineConfig::on(7.5),
        ..off
    };
    assert_eq!(
        fedzero::store::snapshot::cfg_to_json(&on).to_string(),
        "{\"algo\":\"mc2mkp\",\"deadline_s\":7.5,\"incremental\":true,\
         \"max_share\":0.25,\"min_tasks\":2,\"participation\":0.5,\
         \"pipeline\":true,\"rounds\":12,\"seed\":\"feed\",\"shards\":3,\
         \"target_loss\":0.125,\"tasks_per_round\":40}"
    );
    // The unified toggle idiom is equivalent to the explicit
    // constructors — `From` conversions may never drift from on()/off().
    assert_eq!(PipelineConfig::from(true), PipelineConfig::on());
    assert_eq!(PipelineConfig::from(false), PipelineConfig::off());
    assert_eq!(IncrementalConfig::from(true), IncrementalConfig::on());
    assert_eq!(IncrementalConfig::from(false), IncrementalConfig::off());
    assert_eq!(DeadlineConfig::from(Some(7.5)), DeadlineConfig::on(7.5));
    assert_eq!(DeadlineConfig::from(None), DeadlineConfig::off());
    // And the codec round-trips the enabled states exactly.
    let back = fedzero::store::snapshot::cfg_from_json(
        &fedzero::store::snapshot::cfg_to_json(&on),
    )
    .unwrap();
    assert_eq!(back.pipeline, on.pipeline);
    assert_eq!(back.incremental, on.incremental);
    assert_eq!(back.deadline, on.deadline);
}

fn sample_row() -> RoundLog {
    RoundLog {
        round: 2,
        policy: "auto".into(),
        loss: 0.5,
        energy_j: 12.0,
        sched_time_s: 0.0,
        train_time_s: 0.0,
        participants: 3,
        tasks: 8,
    }
}

#[test]
fn journal_line_encoding_is_byte_stable() {
    // Keys are emitted in sorted order and floats in their canonical
    // shortest form, so journals are byte-identical across runs — the
    // property the recovery-smoke diff in CI relies on.
    let entry = JournalEntry {
        round: 2,
        solver: "marin".into(),
        digest: 0xab,
        rng_after: [1, 2, 3, 4],
        row: sample_row(),
    };
    assert_eq!(
        entry.to_json().to_string(),
        "{\"digest\":\"ab\",\"rng\":[\"1\",\"2\",\"3\",\"4\"],\"round\":2,\
         \"row\":{\"energy_j\":12,\"loss\":0.5,\"participants\":3,\
         \"policy\":\"auto\",\"round\":2,\"sched_time_s\":0,\"tasks\":8,\
         \"train_time_s\":0},\"solver\":\"marin\"}"
    );
}

#[test]
fn round_row_encoding_is_byte_stable() {
    assert_eq!(
        row_to_json(&sample_row()).to_string(),
        "{\"energy_j\":12,\"loss\":0.5,\"participants\":3,\
         \"policy\":\"auto\",\"round\":2,\"sched_time_s\":0,\"tasks\":8,\
         \"train_time_s\":0}"
    );
}

// ---- sharded build: digests stay timing-free and shard-count-free ------

fn paper_fleet() -> Vec<ManagedDevice> {
    let inst = Instance::paper_example(5);
    (0..inst.n())
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                inst.costs[i].clone(),
                inst.lower[i],
                inst.upper[i],
            )
        })
        .collect()
}

/// Run a stored sim campaign with the given shard count and incremental
/// mode; return its journal entries and final metrics summary.
fn stored_campaign(
    dir: &std::path::Path,
    shards: usize,
    incremental: bool,
) -> (Vec<JournalEntry>, String) {
    let _ = std::fs::remove_dir_all(dir);
    let cfg = CoordinatorConfig {
        rounds: 5,
        tasks_per_round: 5,
        algo: "auto".into(),
        max_share: 1.0,
        shards,
        incremental: incremental.into(),
        ..CoordinatorConfig::default()
    };
    let mut coord =
        Coordinator::new(cfg, paper_fleet(), SimBackend::new()).unwrap();
    let meta = Json::obj(vec![("kind", Json::Str("golden".into()))]);
    let store = CampaignStore::create(dir, meta, coord.snapshot_json()).unwrap();
    coord.attach_store(store).unwrap();
    coord.run().unwrap();
    let summary = coord.metrics().summary();
    let contents = CampaignStore::read(dir).unwrap();
    let _ = std::fs::remove_dir_all(dir);
    (contents.entries, summary)
}

#[test]
fn sharded_campaign_journal_is_bit_identical_to_unsharded() {
    // The shards knob is a pure build-time optimization: the journal — and
    // therefore every replay/recovery digest — must be byte-for-byte
    // independent of it, and merge timings must never leak into entries.
    let base = std::env::temp_dir().join("fedzero_golden_shards");
    let (plain, plain_summary) = stored_campaign(&base.join("s1"), 1, false);
    let (sharded, sharded_summary) = stored_campaign(&base.join("s3"), 3, false);
    assert_eq!(plain.len(), 5);
    assert_eq!(campaign_digest(&plain), campaign_digest(&sharded));
    for (a, b) in plain.iter().zip(&sharded) {
        // Everything except wall-clock timings must match to the bit.
        assert_eq!(a.round, b.round);
        assert_eq!(a.solver, b.solver);
        assert_eq!(a.digest, b.digest, "round {}", a.round);
        assert_eq!(a.rng_after, b.rng_after, "round {}", a.round);
        assert_eq!(a.row.loss.to_bits(), b.row.loss.to_bits());
        assert_eq!(a.row.energy_j.to_bits(), b.row.energy_j.to_bits());
        assert_eq!(a.row.participants, b.row.participants);
        assert_eq!(a.row.tasks, b.row.tasks);
        assert!(
            !b.to_json().to_string().contains("shard"),
            "journal lines must not carry shard/timing fields"
        );
    }
    // The new metrics fields exist only on the sharded run — and only in
    // metrics, never in the journal: 5 rounds × 3 shards.
    assert!(
        sharded_summary.contains("fleet_shards=15"),
        "{sharded_summary}"
    );
    assert!(
        sharded_summary.contains("shard_merge_ns="),
        "{sharded_summary}"
    );
    assert!(
        !plain_summary.contains("fleet_shards"),
        "unsharded runs must not emit shard metrics: {plain_summary}"
    );
}

#[test]
fn incremental_campaign_journal_is_bit_identical() {
    // The incremental knob is a pure build-time optimization, exactly
    // like shards: journals — and therefore every replay/recovery
    // digest — must be byte-for-byte independent of it. The index
    // surfaces only through the metrics sink.
    let base = std::env::temp_dir().join("fedzero_golden_incremental");
    let (plain, plain_summary) = stored_campaign(&base.join("off"), 1, false);
    let (incr, incr_summary) = stored_campaign(&base.join("on"), 1, true);
    assert_eq!(plain.len(), 5);
    assert_eq!(campaign_digest(&plain), campaign_digest(&incr));
    for (a, b) in plain.iter().zip(&incr) {
        // Everything except wall-clock timings must match to the bit.
        assert_eq!(a.round, b.round);
        assert_eq!(a.solver, b.solver);
        assert_eq!(a.digest, b.digest, "round {}", a.round);
        assert_eq!(a.rng_after, b.rng_after, "round {}", a.round);
        assert_eq!(a.row.loss.to_bits(), b.row.loss.to_bits());
        assert_eq!(a.row.energy_j.to_bits(), b.row.energy_j.to_bits());
        assert_eq!(a.row.participants, b.row.participants);
        assert_eq!(a.row.tasks, b.row.tasks);
        assert!(
            !b.to_json().to_string().contains("incr"),
            "journal lines must not carry index fields"
        );
    }
    // The index counters exist only on the incremental run — and only in
    // metrics, never in the journal: one lazy build, and a dirty-set
    // line per round (zero on this static fleet).
    assert!(
        incr_summary.contains("incr_index_rebuilds=1"),
        "{incr_summary}"
    );
    assert!(incr_summary.contains("incr_dirty="), "{incr_summary}");
    assert!(
        !plain_summary.contains("incr_"),
        "from-scratch runs must not emit index metrics: {plain_summary}"
    );
}
