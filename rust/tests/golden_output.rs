//! Golden tests pinning byte-stable output ordering: journal lines, the
//! `MetricsHub` summary, and the solver registry's applicability text
//! (what `solvers` and `--algo` errors print). The store's journals and
//! the CI recovery diff both depend on these being identical across runs
//! and platforms, so any ordering change must be a conscious one.

use fedzero::metrics::{MetricsHub, RoundLog};
use fedzero::sched::solver::SolverRegistry;
use fedzero::store::journal::JournalEntry;
use fedzero::store::sink::row_to_json;

#[test]
fn registry_describe_order_is_pinned() {
    // Registration order, each with its Table 2 applicability — the exact
    // text `solvers` and `--algo` errors print. A new solver extends this
    // string; nothing may reorder it.
    let registry = SolverRegistry::with_defaults(0);
    assert_eq!(
        registry.describe().join(" "),
        "auto[arb,inc,con,dec,dec∞] mc2mkp[arb,inc,con,dec,dec∞] \
         marin[inc,con] marco[con] mardecun[dec∞] mardec[con,dec,dec∞] \
         bruteforce[arb,inc,con,dec,dec∞] uniform[—] random[—] \
         proportional[—] greedy[—] olar[—]"
    );
}

#[test]
fn registry_names_order_is_pinned() {
    let registry = SolverRegistry::with_defaults(0);
    assert_eq!(
        registry.names(),
        vec![
            "auto",
            "mc2mkp",
            "marin",
            "marco",
            "mardecun",
            "mardec",
            "bruteforce",
            "uniform",
            "random",
            "proportional",
            "greedy",
            "olar",
        ]
    );
}

#[test]
fn metrics_summary_is_byte_stable() {
    // Counters first (name-sorted), then gauges (name-sorted, 4 decimal
    // places) — insertion order must not leak into the output.
    let mut a = MetricsHub::new();
    a.inc("rounds", 2);
    a.inc("dp_solves", 1);
    a.set("train_loss", 0.5);
    a.set("eval_loss", 0.125);
    assert_eq!(
        a.summary(),
        "dp_solves=1 rounds=2 eval_loss=0.1250 train_loss=0.5000"
    );

    let mut b = MetricsHub::new();
    b.set("eval_loss", 0.125);
    b.inc("dp_solves", 1);
    b.set("train_loss", 0.5);
    b.inc("rounds", 2);
    assert_eq!(a.summary(), b.summary(), "insertion order must not matter");
}

fn sample_row() -> RoundLog {
    RoundLog {
        round: 2,
        policy: "auto".into(),
        loss: 0.5,
        energy_j: 12.0,
        sched_time_s: 0.0,
        train_time_s: 0.0,
        participants: 3,
        tasks: 8,
    }
}

#[test]
fn journal_line_encoding_is_byte_stable() {
    // Keys are emitted in sorted order and floats in their canonical
    // shortest form, so journals are byte-identical across runs — the
    // property the recovery-smoke diff in CI relies on.
    let entry = JournalEntry {
        round: 2,
        solver: "marin".into(),
        digest: 0xab,
        rng_after: [1, 2, 3, 4],
        row: sample_row(),
    };
    assert_eq!(
        entry.to_json().to_string(),
        "{\"digest\":\"ab\",\"rng\":[\"1\",\"2\",\"3\",\"4\"],\"round\":2,\
         \"row\":{\"energy_j\":12,\"loss\":0.5,\"participants\":3,\
         \"policy\":\"auto\",\"round\":2,\"sched_time_s\":0,\"tasks\":8,\
         \"train_time_s\":0},\"solver\":\"marin\"}"
    );
}

#[test]
fn round_row_encoding_is_byte_stable() {
    assert_eq!(
        row_to_json(&sample_row()).to_string(),
        "{\"energy_j\":12,\"loss\":0.5,\"participants\":3,\
         \"policy\":\"auto\",\"round\":2,\"sched_time_s\":0,\"tasks\":8,\
         \"train_time_s\":0}"
    );
}
