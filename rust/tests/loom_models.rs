//! Loom models of the repo's three threading protocols.
//!
//! Compiled only under `--cfg loom`: CI's loom job adds the `loom` dev
//! dependency (`cargo add --dev loom`) and sets `RUSTFLAGS="--cfg loom"`,
//! so the committed manifest stays offline-buildable and this target is
//! empty in a normal `cargo test`.
//!
//! The real implementations use `std::thread` directly
//! (`runtime/pool.rs`), which loom cannot instrument, so each model
//! restates the *protocol* — the spawn/join shape and the memory-order
//! assumptions — over loom's checked primitives and lets the model
//! checker enumerate every interleaving:
//!
//! 1. `parallel_map`: workers complete in any order, but the caller
//!    extends the output in spawn order, so results are input-ordered
//!    and every worker's writes are visible after its join.
//! 2. `BackgroundTask`: `join` returns the closure's value and is a
//!    happens-before edge for its side effects — `finish_train` may read
//!    anything `begin_train`'s thread wrote, even `Relaxed`.
//! 3. The pipelined coordinator's speculation overlap window: the next
//!    round is solved from a pre-training snapshot while training
//!    mutates live state; the adoption guard (a fingerprint compare,
//!    `sched/incremental.rs`) accepts the speculative result iff the
//!    snapshot still matches, so an adopted result always equals what a
//!    serial re-solve of the live state would produce.
#![cfg(loom)]

use loom::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

/// Stand-in for a deterministic solve: any pure function of the
/// snapshot works, this one just mixes the bits around.
fn solve(x: u64) -> u64 {
    x.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0x2209_0621
}

#[test]
fn parallel_map_joins_in_spawn_order() {
    loom::model(|| {
        let started = Arc::new(AtomicUsize::new(0));
        let chunks = [vec![1u64, 2], vec![3, 4]];
        let handles: Vec<_> = chunks
            .into_iter()
            .map(|chunk| {
                let started = Arc::clone(&started);
                thread::spawn(move || {
                    started.fetch_add(1, Ordering::Relaxed);
                    chunk.into_iter().map(|x| x * 2).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut out = Vec::new();
        for h in handles {
            out.extend(h.join().unwrap());
        }
        assert_eq!(out, vec![2, 4, 6, 8], "spawn order, not completion order");
        assert_eq!(started.load(Ordering::Relaxed), 2, "both joins are visibility edges");
    });
}

#[test]
fn background_task_join_is_a_happens_before_edge() {
    loom::model(|| {
        let effect = Arc::new(AtomicUsize::new(0));
        let task = {
            let effect = Arc::clone(&effect);
            thread::spawn(move || {
                // Relaxed on purpose: visibility must come from the
                // join edge alone, exactly what BackgroundTask promises.
                effect.store(1, Ordering::Relaxed);
                42u64
            })
        };
        let value = task.join().unwrap();
        assert_eq!(value, 42);
        assert_eq!(effect.load(Ordering::Relaxed), 1);
    });
}

/// One pass through the overlap window. The trainer thread runs
/// concurrently with the speculative solve; the guard decides at join
/// time. The assertion is the pipelined driver's whole correctness
/// claim: whatever was adopted equals a serial re-solve of the live
/// state.
fn overlap_window(train_mutates: bool) {
    loom::model(move || {
        let live = Arc::new(AtomicU64::new(7));
        let trainer = {
            let live = Arc::clone(&live);
            thread::spawn(move || {
                if train_mutates {
                    live.fetch_add(1, Ordering::SeqCst);
                }
            })
        };
        // Speculative leg: snapshot, then solve from the snapshot while
        // the trainer may or may not have mutated the live state yet.
        let snapshot = live.load(Ordering::SeqCst);
        let speculative = solve(snapshot);
        trainer.join().unwrap();
        // Adoption guard: fingerprint compare against the live state.
        let now = live.load(Ordering::SeqCst);
        let adopted = if now == snapshot { speculative } else { solve(now) };
        assert_eq!(adopted, solve(now), "adopted result == serial re-solve");
    });
}

#[test]
fn speculation_guard_with_quiet_training() {
    overlap_window(false);
}

#[test]
fn speculation_guard_with_mutating_training() {
    overlap_window(true);
}
