//! Differential proof of incremental round re-derivation:
//! **persistent-index builds ≡ from-scratch builds**, bit-for-bit, under
//! churn, for every registered solver.
//!
//! The persistent class index (`rust/src/sched/incremental.rs`) keeps
//! device→class buckets alive across rounds and re-classifies only the
//! dirty set the coordinator's recosting emits. The acceptance bar
//! mirrors the shard and pipeline suites:
//!
//! * a scenario-diverse churn fuzz — Table 2 cost families × adversarial
//!   limit patterns × duplication shapes × churn shapes (availability
//!   flips, battery death, p% cost drift, device join/retire) — that
//!   keeps generating until each of the 12 registered solvers has
//!   accumulated **≥ 200** zero-divergence cases (the shared oracle is
//!   `fedzero::testkit::instances::check_incremental_churn`: identical
//!   digest, class bits, workload, relaxation flag, assignment bits, and
//!   cost bits at every scripted round);
//! * full-campaign equivalence through the coordinator — a battery +
//!   drift + dropout fleet where `--incremental on` must reproduce the
//!   off-path campaign row-for-row and state-bit-for-state-bit, alone
//!   and composed with the pipelined driver and sharded selection.

use fedzero::coordinator::{Coordinator, CoordinatorConfig, ManagedDevice, SimBackend};
use fedzero::energy::battery::Battery;
use fedzero::energy::power::{Behavior, PowerModel};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::solver::SolverRegistry;
use fedzero::testkit::instances::{
    check_incremental_churn, Case, ChurnCase, ChurnPattern, DupShape, Family,
    LimitPattern,
};

use std::collections::BTreeMap;

/// Every registered solver name — derived from the registry so a newly
/// registered solver automatically joins the fuzz (and must be
/// classified by [`runs_on`], which panics on unknown names).
fn all_solvers() -> Vec<&'static str> {
    SolverRegistry::with_defaults(0).names()
}

/// Which scenario cells a solver joins the churn fuzz on — the same
/// regime envelope the shard suite proves path equivalence inside
/// (outside a solver's regime the two identical-bit solves still agree
/// trivially, but the solver may legitimately reject the instance, so
/// coverage there proves nothing extra). Drift churn wraps costs in
/// `Scaled`, which preserves the base family's marginal regime.
fn runs_on(name: &str, family: Family, tiny: bool) -> bool {
    match name {
        "auto" | "mc2mkp" | "uniform" | "random" | "proportional" | "greedy"
        | "olar" => true,
        "bruteforce" => tiny,
        "marin" => matches!(family, Family::Convex | Family::Affine),
        "marco" => matches!(family, Family::Affine),
        "mardec" | "mardecun" => {
            matches!(family, Family::Concave | Family::Affine)
        }
        other => panic!(
            "solver '{other}' is registered but unclassified — add it to \
             runs_on so the churn fuzz covers it"
        ),
    }
}

#[test]
fn fuzz_incremental_churn_reaches_200_cases_per_solver() {
    const TARGET: usize = 200;
    let solvers = all_solvers();
    let mut counts: BTreeMap<&str, usize> =
        solvers.iter().map(|&s| (s, 0usize)).collect();
    // Scenario cycle engineered so every solver's applicable combos recur
    // often (marco is the rarest at 4-in-10) and every churn shape
    // appears at least twice per cycle.
    let combos: [(Family, LimitPattern, DupShape, ChurnPattern); 10] = [
        (
            Family::Convex,
            LimitPattern::Both,
            DupShape::Random,
            ChurnPattern::AvailabilityFlip,
        ),
        (
            Family::Affine,
            LimitPattern::Unlimited,
            DupShape::SingleClass,
            ChurnPattern::BatteryDeath,
        ),
        (
            Family::Concave,
            LimitPattern::UnlimitedWithLower,
            DupShape::Random,
            ChurnPattern::DriftP { pct: 10 },
        ),
        (
            Family::Tabulated,
            LimitPattern::Both,
            DupShape::Random,
            ChurnPattern::JoinRetire,
        ),
        (
            Family::Affine,
            LimitPattern::UpperOnly,
            DupShape::Random,
            ChurnPattern::DriftP { pct: 2 },
        ),
        (
            Family::Concave,
            LimitPattern::Both,
            DupShape::AllUnique,
            ChurnPattern::BatteryDeath,
        ),
        (
            Family::Convex,
            LimitPattern::TightLower,
            DupShape::Random,
            ChurnPattern::DriftP { pct: 25 },
        ),
        (
            Family::Affine,
            LimitPattern::Pinned,
            DupShape::SingleClass,
            ChurnPattern::AvailabilityFlip,
        ),
        (
            Family::Concave,
            LimitPattern::UnlimitedWithLower,
            DupShape::SingleClass,
            ChurnPattern::JoinRetire,
        ),
        (
            Family::Affine,
            LimitPattern::Both,
            DupShape::Random,
            ChurnPattern::BatteryDeath,
        ),
    ];
    let mut case_idx: u64 = 0;
    while counts.values().any(|&c| c < TARGET) {
        assert!(
            case_idx < 20_000,
            "fuzz failed to reach {TARGET} cases/solver: {counts:?}"
        );
        let (family, limits, dup, pattern) =
            combos[(case_idx as usize) % combos.len()];
        let base = Case {
            seed: 0x1DE0 ^ case_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            family,
            limits,
            dup,
            distinct: 3,
            max_dup: 2,
            t: 4 + (case_idx as usize % 5),
        };
        let churn = ChurnCase {
            base,
            pattern,
            rounds: 5,
            // Cycle the round-transform knobs so the share cap's
            // raw-class merges and the joined lower stage both recur.
            max_share: [1.0, 0.6, 0.35][(case_idx as usize) % 3],
            min_tasks: (case_idx as usize) % 2,
        };
        let inst = base.build();
        let tiny = inst.n() <= 4 && inst.tasks <= 8;
        for &name in &solvers {
            if !runs_on(name, family, tiny) {
                continue;
            }
            check_incremental_churn(&churn, name)
                .unwrap_or_else(|e| panic!("case {churn:?}: {e}"));
            *counts.get_mut(name).unwrap() += 1;
        }
        case_idx += 1;
    }
    for (name, c) in counts {
        assert!(c >= TARGET, "{name}: only {c} zero-divergence cases");
    }
    println!("churn fuzz complete after {case_idx} generated scenarios");
}

// ---- full campaigns through the coordinator ----------------------------

/// A dynamic fleet with duplicated specs, a lower limit, mixed cost
/// shapes, and a draining battery — every dirty-set source (drift
/// recosting, dropout drains, battery-draining training) at once.
fn dynamic_fleet() -> Vec<ManagedDevice> {
    let affine = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
    let quad = CostFn::Quadratic { fixed: 0.5, a: 0.25, b: 0.5 };
    let sqrtish = CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.6 };
    let power = PowerModel {
        idle_w: 0.1,
        busy_w: 2.0,
        batch_latency_s: 0.5,
        behavior: Behavior::Linear,
        curvature: 0.0,
    }; // 1 J per task
    vec![
        ManagedDevice::abstract_resource(0, affine.clone(), 0, 4),
        ManagedDevice::abstract_resource(1, affine, 0, 4),
        ManagedDevice::abstract_resource(2, quad, 1, 5),
        ManagedDevice::abstract_resource(3, sqrtish.clone(), 0, 6),
        ManagedDevice::abstract_resource(4, sqrtish, 0, 6),
        ManagedDevice {
            id: 5,
            cost: power.cost_fn(),
            lower: 0,
            data_cap: 8,
            battery: Some(Battery {
                capacity_wh: 60.0 / 3600.0, // 60 J total
                level: 1.0,
                round_budget_frac: 0.4,
            }),
            power: Some(power),
            drift: 1.0,
            deadline_cap: usize::MAX,
        },
    ]
}

/// Everything a campaign decided, bit-exact: per-round row bits plus a
/// fingerprint of the state the snapshot would persist. The metrics
/// subtree is deliberately excluded — `incr_*` (and, pipelined,
/// `pipeline_*`) counters are the intended observable difference.
fn run_campaign(
    solver: &str,
    seed: u64,
    incremental: bool,
    pipeline: bool,
    shards: usize,
) -> (Vec<(u64, u64, usize, usize)>, String) {
    let cfg = CoordinatorConfig {
        rounds: 8,
        tasks_per_round: 8,
        algo: solver.to_string(),
        participation: 0.8,
        max_share: 1.0,
        seed,
        shards,
        pipeline: pipeline.into(),
        incremental: incremental.into(),
        ..CoordinatorConfig::default()
    };
    let rounds = cfg.rounds;
    let mut c = Coordinator::new(cfg, dynamic_fleet(), SimBackend::new()).unwrap();
    c.set_dynamics(DynamicsConfig::mobile(6));
    // Scenario-mismatched solvers abort every round; aborts must be
    // identical across build paths too.
    while c.rounds_run() < rounds {
        let _ = c.round();
    }
    let rows = c
        .log()
        .rows()
        .iter()
        .map(|r| (r.loss.to_bits(), r.energy_j.to_bits(), r.participants, r.tasks))
        .collect();
    let state = c.snapshot_json();
    let fingerprint = ["rng", "devices", "pool", "ledger", "last_loss", "next_round"]
        .iter()
        .map(|k| format!("{k}={}", state.get(k).expect("snapshot field").to_string()))
        .collect::<Vec<_>>()
        .join(";");
    (rows, fingerprint)
}

/// The coordinator-level property: for every registered solver, the
/// incremental index drives the exact same dynamic campaign as the
/// from-scratch build — alone, under sharded selection, through the
/// pipelined speculative path, and under both at once.
#[test]
fn incremental_campaigns_match_from_scratch_for_all_solvers() {
    let solvers = all_solvers();
    assert_eq!(solvers.len(), 12, "sweep must cover every registered solver");
    for (si, solver) in solvers.iter().enumerate() {
        for rep in 0..2u64 {
            let seed = 0xFEED_5EED ^ ((si as u64) << 8) ^ rep;
            let reference = run_campaign(solver, seed, false, false, 1);
            for (pipeline, shards) in
                [(false, 1usize), (true, 1), (false, 3), (true, 3)]
            {
                let incr = run_campaign(solver, seed, true, pipeline, shards);
                assert_eq!(
                    reference, incr,
                    "solver {solver}, seed {seed:#x}, pipeline {pipeline}, \
                     shards {shards}"
                );
            }
        }
    }
}

/// Paper-style abstract fleets (no battery, no power model) must also be
/// identical — the index's mains-powered no-drain path.
#[test]
fn incremental_matches_on_an_abstract_paper_fleet() {
    let inst = Instance::paper_example(5);
    let devices = || -> Vec<ManagedDevice> {
        (0..inst.n())
            .map(|i| {
                ManagedDevice::abstract_resource(
                    i,
                    inst.costs[i].clone(),
                    inst.lower[i],
                    inst.upper[i],
                )
            })
            .collect()
    };
    let run = |incremental: bool| {
        let cfg = CoordinatorConfig {
            rounds: 4,
            tasks_per_round: 5,
            algo: "mc2mkp".into(),
            max_share: 1.0,
            incremental: incremental.into(),
            ..CoordinatorConfig::default()
        };
        let mut c = Coordinator::new(cfg, devices(), SimBackend::new()).unwrap();
        while c.rounds_run() < 4 {
            c.round().unwrap();
        }
        c.log()
            .rows()
            .iter()
            .map(|r| (r.energy_j.to_bits(), r.participants, r.tasks))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(false), run(true));
}
