//! End-to-end FL integration tests over the real PJRT runtime.
//!
//! All tests are `#[ignore]`d with an explicit reason: they require
//! `artifacts/` (run `make artifacts`) **and** a real PJRT plugin — the
//! vendored offline `xla` stub (rust/vendor/xla) loads HLO but cannot
//! execute it, so even with artifacts present these can only pass against
//! real bindings. Run with `cargo test -- --ignored` in such an
//! environment; the in-process guard still skips cleanly when artifacts
//! are absent.

use std::path::Path;

use fedzero::config::{Policy, TrainConfig};
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::BehaviorMix;
use fedzero::fl::data::Dataset;
use fedzero::fl::Server;
use fedzero::runtime::{Dtype, ModelRuntime};
use fedzero::util::rng::Rng;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("fl_integration: artifacts/ missing, skipping (run `make artifacts`)");
        None
    }
}

fn mlp_cfg() -> TrainConfig {
    TrainConfig {
        rounds: 6,
        devices: 8,
        tasks_per_round: 48,
        model: "mlp".into(),
        seed: 11,
        ..TrainConfig::default()
    }
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn runtime_loads_and_steps() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(dir, "mlp").unwrap();
    let spec = rt.spec().clone();
    assert_eq!(spec.input_dtype, Dtype::F32);

    let mut rng = Rng::new(3);
    let ds = Dataset::synth(&spec, 128, &mut rng);
    let shard = ds.full_shard();
    let b = ds.batch(&spec, &shard, &mut rng).unwrap();
    let x = rt.input_literal_f32(&b.x_f32).unwrap();
    let y = rt.label_literal(&b.y).unwrap();

    let p0 = rt.initial_params();
    let loss0 = rt.eval_step(&p0, &x, &y).unwrap();
    assert!(loss0.is_finite() && loss0 > 0.0);

    // A train step on the same batch must lower the loss on that batch.
    let (p1, train_loss) = rt.train_step(&p0, &x, &y).unwrap();
    assert!((train_loss - loss0).abs() < 1e-4, "{train_loss} vs {loss0}");
    let loss1 = rt.eval_step(&p1, &x, &y).unwrap();
    assert!(loss1 < loss0, "one SGD step should reduce batch loss: {loss1} !< {loss0}");
    // Params actually changed.
    assert_ne!(p0, p1);
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn train_step_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(dir, "mlp").unwrap();
    let mut rng = Rng::new(5);
    let ds = Dataset::synth(rt.spec(), 64, &mut rng);
    let b = ds.batch(rt.spec(), &ds.full_shard(), &mut rng).unwrap();
    let x = rt.input_literal_f32(&b.x_f32).unwrap();
    let y = rt.label_literal(&b.y).unwrap();
    let p = rt.initial_params();
    let (a, la) = rt.train_step(&p, &x, &y).unwrap();
    let (b2, lb) = rt.train_step(&p, &x, &y).unwrap();
    assert_eq!(la, lb);
    assert_eq!(a, b2);
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn server_converges_on_mlp() {
    let Some(_) = artifacts() else { return };
    let mut cfg = mlp_cfg();
    cfg.rounds = 10;
    let mut server = Server::new(cfg, BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
    server.run().unwrap();
    let rows = server.log().rows();
    assert_eq!(rows.len(), 10);
    let first = rows[0].loss;
    let last = rows.last().unwrap().loss;
    assert!(
        last < first * 0.5,
        "training did not converge: {first} → {last}"
    );
    assert!(server.ledger().total() > 0.0);
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn same_seed_same_trajectory() {
    let Some(_) = artifacts() else { return };
    let run = || {
        let mut server =
            Server::new(mlp_cfg(), BehaviorMix::Homogeneous(Behavior::Convex)).unwrap();
        server.run().unwrap();
        server
            .log()
            .rows()
            .iter()
            .map(|r| (r.loss, r.energy_j))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn optimal_policy_uses_less_energy_than_uniform() {
    let Some(_) = artifacts() else { return };
    let mix = BehaviorMix::Homogeneous(Behavior::Convex);
    let (_, e_opt) = Server::train_once(mlp_cfg(), Policy::Auto, mix).unwrap();
    let (_, e_uni) = Server::train_once(mlp_cfg(), Policy::Uniform, mix).unwrap();
    assert!(
        e_opt < e_uni,
        "optimal {e_opt} J should beat uniform {e_uni} J under convex costs"
    );
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn energy_ledger_matches_round_logs() {
    let Some(_) = artifacts() else { return };
    let mut server =
        Server::new(mlp_cfg(), BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
    server.run().unwrap();
    let from_rounds: f64 = server.log().rows().iter().map(|r| r.energy_j).sum();
    assert!((from_rounds - server.ledger().total()).abs() < 1e-6);
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn max_share_caps_concentration() {
    let Some(_) = artifacts() else { return };
    let mut cfg = mlp_cfg();
    cfg.rounds = 3;
    cfg.max_share = 0.2;
    // Linear costs: unconstrained optimum would put everything on one
    // device; max_share must prevent that.
    let mut server = Server::new(cfg, BehaviorMix::Homogeneous(Behavior::Linear)).unwrap();
    server.run().unwrap();
    assert!(
        server.ledger().max_device_share() < 0.9,
        "share {}",
        server.ledger().max_device_share()
    );
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn transformer_round_runs() {
    let Some(dir) = artifacts() else { return };
    if let Err(e) = ModelRuntime::load(dir, "transformer") {
        eprintln!("transformer artifact missing ({e}), skipping");
        return;
    }
    let cfg = TrainConfig {
        rounds: 2,
        devices: 4,
        tasks_per_round: 8,
        model: "transformer".into(),
        seed: 2,
        ..TrainConfig::default()
    };
    let mut server = Server::new(cfg, BehaviorMix::Mixed).unwrap();
    server.run().unwrap();
    let rows = server.log().rows();
    assert_eq!(rows.len(), 2);
    assert!(rows.iter().all(|r| r.loss.is_finite()));
}

#[test]
#[ignore = "needs PJRT artifacts (make artifacts) + a real xla backend; the vendored offline stub cannot execute HLO"]
fn missing_model_is_clean_error() {
    let Some(dir) = artifacts() else { return };
    let Err(err) = ModelRuntime::load(dir, "nonexistent") else {
        panic!("loading a nonexistent model must fail");
    };
    assert!(format!("{err}").contains("not in manifest"));
}
