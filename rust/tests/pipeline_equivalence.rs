//! Differential proof of the pipelined round driver: **pipelined ≡
//! serial**, bit-for-bit, for every registered solver.
//!
//! The pipelined coordinator overlaps round `r + 1`'s Scheduling with
//! round `r`'s Training by speculating against predicted post-round
//! state and adopting the speculation only when a guard digest over
//! everything Scheduling reads matches (`rust/src/coordinator`). The
//! acceptance bar here mirrors the shard suite's: a scenario-diverse
//! generator sweep (Table 2 cost families × adversarial limit patterns ×
//! duplication shapes, ≥ 200 cases total) across **all 12 registered
//! solvers**, with fleet dynamics both off and on, comparing
//!
//! * every round row's loss/energy **bits**, participants, and tasks,
//! * the final RNG state (equal state ⇒ every stochastic decision
//!   matched),
//! * the snapshot fingerprint (devices, batteries, drift, pool, ledger),
//!
//! plus journaled-campaign digests through a real store, and a
//! SIGKILL-style kill/resume **mid-pipeline** (a speculation in flight
//! when the process dies) that must still reproduce the serial clean
//! run's campaign digest — pipelining never reaches the journal.

use std::path::{Path, PathBuf};

use fedzero::coordinator::{Coordinator, CoordinatorConfig, ManagedDevice, SimBackend};
use fedzero::energy::battery::Battery;
use fedzero::energy::power::{Behavior, PowerModel};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::sched::instance::Instance;
use fedzero::sched::solver::SolverRegistry;
use fedzero::store::journal::{campaign_digest, JournalEntry};
use fedzero::store::{get, snapshot as snap, CampaignStore};
use fedzero::testkit::instances::{
    Case, ALL_DUP_SHAPES, ALL_FAMILIES, ALL_LIMIT_PATTERNS,
};
use fedzero::util::json::Json;

/// Abstract paper-style fleet mirroring a generated instance's devices.
fn managed(inst: &Instance) -> Vec<ManagedDevice> {
    (0..inst.n())
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                inst.costs[i].clone(),
                inst.lower[i],
                inst.upper[i],
            )
        })
        .collect()
}

fn cfg_for(case: &Case, algo: &str, participation: f64, pipeline: bool) -> CoordinatorConfig {
    let inst = case.build();
    CoordinatorConfig {
        rounds: 5,
        tasks_per_round: inst.tasks,
        algo: algo.to_string(),
        participation,
        min_tasks: 0,
        max_share: 1.0,
        seed: case.seed ^ 0xA5A5,
        target_loss: None,
        shards: 1,
        pipeline: pipeline.into(),
        incremental: false.into(),
    }
}

/// Everything a campaign decided, bit-exact: per-round row bits plus a
/// fingerprint of the state the snapshot would persist (RNG, devices
/// incl. batteries and drift, selection pool, ledger, last loss). The
/// metrics subtree is deliberately excluded — `pipeline_*` counters are
/// the one intended observable difference.
fn run_campaign(
    case: &Case,
    algo: &str,
    mobile: bool,
    participation: f64,
    pipeline: bool,
) -> (Vec<(u64, u64, usize, usize)>, String) {
    let inst = case.build();
    let cfg = cfg_for(case, algo, participation, pipeline);
    let rounds = cfg.rounds;
    let mut c = Coordinator::new(cfg, managed(&inst), SimBackend::new()).unwrap();
    if mobile {
        c.set_dynamics(DynamicsConfig::mobile(inst.n()));
    }
    // Scenario-mismatched solvers (e.g. MarDecUn on a limited fleet)
    // abort every round; aborts must pipeline identically too.
    while c.rounds_run() < rounds {
        let _ = c.round();
    }
    let rows = c
        .log()
        .rows()
        .iter()
        .map(|r| (r.loss.to_bits(), r.energy_j.to_bits(), r.participants, r.tasks))
        .collect();
    let state = c.snapshot_json();
    let fingerprint = ["rng", "devices", "pool", "ledger", "last_loss", "next_round"]
        .iter()
        .map(|k| format!("{k}={}", state.get(k).expect("snapshot field").to_string()))
        .collect::<Vec<_>>()
        .join(";");
    (rows, fingerprint)
}

/// The core property: across ≥ 200 generator cases spanning every
/// scenario axis, each of the 12 registered solvers drives the exact
/// same campaign with the pipeline on as off.
#[test]
fn pipelined_matches_serial_across_generator_cases_for_all_solvers() {
    let registry = SolverRegistry::with_defaults(0);
    let solvers = registry.names();
    assert_eq!(solvers.len(), 12, "sweep must cover every registered solver");
    let mut cases = 0usize;
    for (si, solver) in solvers.iter().enumerate() {
        for (fi, &family) in ALL_FAMILIES.iter().enumerate() {
            for rep in 0..5u64 {
                let case = Case {
                    seed: 0x91BE_11E5
                        ^ ((si as u64) << 32)
                        ^ ((fi as u64) << 16)
                        ^ rep,
                    family,
                    limits: ALL_LIMIT_PATTERNS
                        [(si + fi + rep as usize) % ALL_LIMIT_PATTERNS.len()],
                    dup: ALL_DUP_SHAPES[(si + rep as usize) % ALL_DUP_SHAPES.len()],
                    distinct: 3,
                    max_dup: 3,
                    t: 4 + (rep as usize) * 2,
                };
                // Alternate dynamics and partial participation so the
                // speculative Recosting replay (drift, churn, dropout
                // draws) and the selection draw are both exercised.
                let mobile = rep % 2 == 0;
                let participation = if rep % 3 == 0 { 1.0 } else { 0.8 };
                let serial = run_campaign(&case, solver, mobile, participation, false);
                let piped = run_campaign(&case, solver, mobile, participation, true);
                assert_eq!(
                    serial, piped,
                    "solver {solver}, mobile {mobile}, case {case:?}"
                );
                cases += 1;
            }
        }
    }
    assert!(cases >= 200, "only {cases} generator cases ran");
}

// ---- journaled campaigns: digests through a real store -----------------

const ROUNDS: usize = 12;
const SNAPSHOT_EVERY: usize = 4;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("fedzero_pipeline_equivalence")
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A dynamic fleet with duplicated specs, a lower limit, mixed cost
/// shapes, and a draining battery — the state speculation must predict.
fn dynamic_fleet() -> Vec<ManagedDevice> {
    use fedzero::sched::costs::CostFn;
    let affine = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
    let quad = CostFn::Quadratic { fixed: 0.5, a: 0.25, b: 0.5 };
    let sqrtish = CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.6 };
    let power = PowerModel {
        idle_w: 0.1,
        busy_w: 2.0,
        batch_latency_s: 0.5,
        behavior: Behavior::Linear,
        curvature: 0.0,
    }; // 1 J per task
    vec![
        ManagedDevice::abstract_resource(0, affine.clone(), 0, 4),
        ManagedDevice::abstract_resource(1, affine, 0, 4),
        ManagedDevice::abstract_resource(2, quad, 1, 5),
        ManagedDevice::abstract_resource(3, sqrtish.clone(), 0, 6),
        ManagedDevice::abstract_resource(4, sqrtish, 0, 6),
        ManagedDevice {
            id: 5,
            cost: power.cost_fn(),
            lower: 0,
            data_cap: 8,
            battery: Some(Battery {
                capacity_wh: 60.0 / 3600.0, // 60 J total
                level: 1.0,
                round_budget_frac: 0.4,
            }),
            power: Some(power),
            drift: 1.0,
            deadline_cap: usize::MAX,
        },
    ]
}

fn stored_cfg(solver: &str, seed: u64, pipeline: bool) -> CoordinatorConfig {
    CoordinatorConfig {
        rounds: ROUNDS,
        tasks_per_round: 8,
        algo: solver.to_string(),
        participation: 0.8,
        max_share: 1.0,
        seed,
        pipeline: pipeline.into(),
        ..CoordinatorConfig::default()
    }
}

fn new_stored(
    solver: &str,
    seed: u64,
    pipeline: bool,
    dir: &Path,
) -> Coordinator<SimBackend> {
    let cfg = stored_cfg(solver, seed, pipeline);
    let mut c =
        Coordinator::new(cfg.clone(), dynamic_fleet(), SimBackend::new()).unwrap();
    c.set_dynamics(DynamicsConfig::mobile(6));
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(SNAPSHOT_EVERY as f64)),
        ("cfg", snap::cfg_to_json(&cfg)),
    ]);
    let store = CampaignStore::create(dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
    c
}

fn drive(c: &mut Coordinator<SimBackend>, upto: usize) {
    while c.rounds_run() < upto {
        let _ = c.round_stored();
    }
}

fn run_stored(solver: &str, seed: u64, pipeline: bool, dir: &Path) -> Vec<JournalEntry> {
    let mut c = new_stored(solver, seed, pipeline, dir);
    drive(&mut c, ROUNDS);
    CampaignStore::read(dir).unwrap().entries
}

fn assert_entries_equal(ctx: &str, a: &[JournalEntry], b: &[JournalEntry]) {
    assert_eq!(a.len(), b.len(), "{ctx}: campaign length");
    for (ea, eb) in a.iter().zip(b) {
        assert_eq!(ea.round, eb.round, "{ctx}: round index");
        assert_eq!(ea.solver, eb.solver, "{ctx}: effective solver, round {}", ea.round);
        assert_eq!(ea.digest, eb.digest, "{ctx}: digest, round {}", ea.round);
        assert_eq!(ea.rng_after, eb.rng_after, "{ctx}: RNG, round {}", ea.round);
        assert_eq!(
            ea.row.energy_j.to_bits(),
            eb.row.energy_j.to_bits(),
            "{ctx}: energy, round {}",
            ea.round
        );
    }
    assert_eq!(campaign_digest(a), campaign_digest(b), "{ctx}: campaign digest");
}

/// Journal-level equality: a pipelined stored campaign writes the exact
/// journal a serial one does — entry by entry, digest for digest —
/// including the warm-DP solver, the `auto` dispatcher, and the seeded
/// `random` baseline.
#[test]
fn pipelined_campaign_digest_equals_serial_through_a_store() {
    for (i, solver) in ["auto", "mc2mkp", "random", "marin"].iter().enumerate() {
        let seed = 300 + i as u64;
        let serial_dir = scratch(&format!("digest_{solver}_serial"));
        let piped_dir = scratch(&format!("digest_{solver}_piped"));
        let serial = run_stored(solver, seed, false, &serial_dir);
        let piped = run_stored(solver, seed, true, &piped_dir);
        assert_entries_equal(solver, &serial, &piped);
        let _ = std::fs::remove_dir_all(&serial_dir);
        let _ = std::fs::remove_dir_all(&piped_dir);
    }
}

/// Kill/resume **mid-pipeline**: the pipelined campaign is dropped while
/// a speculation for the next round is in flight (every committed round
/// spawns one), resumed from its store — `resume` picks the pipeline
/// mode back up from the persisted cfg — and must land on the serial
/// clean run's exact campaign digest. Speculative state dies with the
/// process and is simply re-derived; the journal never saw it.
#[test]
fn kill_and_resume_mid_pipeline_matches_clean_serial_run() {
    let solver = "auto";
    let seed = 777;
    let clean_dir = scratch("kill_clean");
    let clean = run_stored(solver, seed, false, &clean_dir);

    for r in [1usize, 5, 9] {
        let crash_dir = scratch(&format!("kill_crash_{r}"));
        {
            let mut c = new_stored(solver, seed, true, &crash_dir);
            drive(&mut c, r);
            // Dropping the coordinator IS the crash; the in-flight
            // speculation for round r (created while round r-1 trained)
            // dies un-journaled with it.
            assert!(
                c.metrics().counter("pipeline_speculations") > 0,
                "campaign must actually have speculated before the kill"
            );
        }
        let (store, contents) = CampaignStore::resume(&crash_dir).unwrap();
        let cfg = snap::cfg_from_json(get(&contents.meta, "cfg").unwrap()).unwrap();
        assert!(cfg.pipeline.enabled, "resume must restore the pipeline mode");
        let mut c = Coordinator::restore(
            cfg,
            &contents.snapshot,
            &contents.entries,
            SimBackend::new(),
            None,
        )
        .unwrap();
        c.attach_store(store).unwrap();
        drive(&mut c, ROUNDS);
        let resumed = CampaignStore::read(&crash_dir).unwrap().entries;
        assert_entries_equal(&format!("crash at {r}"), &clean, &resumed);
        let _ = std::fs::remove_dir_all(&crash_dir);
    }
    let _ = std::fs::remove_dir_all(&clean_dir);
}

/// Speculation pays off where it should: on the sim backend the drain
/// prediction is exact, so a dynamic campaign (churn + drift + dropout +
/// battery) adopts every speculation it makes.
#[test]
fn dynamic_sim_campaign_adopts_every_speculation() {
    let dir = scratch("hit_rate");
    let mut c = new_stored("auto", 42, true, &dir);
    drive(&mut c, ROUNDS);
    let spec = c.metrics().counter("pipeline_speculations");
    let hits = c.metrics().counter("pipeline_hits");
    let misses = c.metrics().counter("pipeline_misses");
    assert!(spec > 0, "a {ROUNDS}-round campaign must speculate");
    assert_eq!(misses, 0, "sim predictions are exact; nothing may miss");
    assert_eq!(hits, spec, "every speculation must be adopted");
    let _ = std::fs::remove_dir_all(&dir);
}
