//! Telemetry purity at the store level (the PR's acceptance bar): a
//! traced campaign — with every optimization knob composed (pipelined
//! rounds + sharded build + incremental re-derivation, under fleet
//! churn) — journals bit-identically to an untraced one, replays to the
//! same campaign digest, and the trace file itself is valid, balanced
//! Trace Event JSONL covering the store spans too.

use std::path::Path;

use fedzero::coordinator::{
    Coordinator, CoordinatorConfig, ManagedDevice, PipelineConfig, SimBackend,
};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::obs::ChromeTraceSink;
use fedzero::sched::instance::Instance;
use fedzero::store::journal::campaign_digest;
use fedzero::store::{CampaignStore, StoreContents};
use fedzero::util::json::Json;

const ROUNDS: usize = 8;

fn fleet() -> Vec<ManagedDevice> {
    let inst = Instance::paper_example(5);
    (0..inst.n())
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                inst.costs[i].clone(),
                inst.lower[i],
                inst.upper[i],
            )
        })
        .collect()
}

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        rounds: ROUNDS,
        tasks_per_round: 5,
        algo: "auto".into(),
        max_share: 1.0,
        shards: 3,
        pipeline: PipelineConfig::on(),
        incremental: true.into(),
        ..CoordinatorConfig::default()
    }
}

/// Run one stored campaign (snapshot cadence 2 so periodic snapshots —
/// and their spans — happen), optionally traced; return the store
/// contents read back from disk.
fn campaign(dir: &Path, trace: Option<&Path>) -> StoreContents {
    let _ = std::fs::remove_dir_all(dir);
    let mut coord = Coordinator::new(cfg(), fleet(), SimBackend::new()).unwrap();
    // Churn/drift/dropout so speculation guards and the incremental
    // dirty-set genuinely vary across rounds.
    coord.set_dynamics(DynamicsConfig::mobile(5));
    if let Some(path) = trace {
        coord.set_tracer(Box::new(ChromeTraceSink::create(path).unwrap()));
    }
    let meta = Json::obj(vec![
        ("kind", Json::Str("obs".into())),
        ("snapshot_every", Json::Num(2.0)),
    ]);
    let store = CampaignStore::create(dir, meta, coord.snapshot_json()).unwrap();
    coord.attach_store(store).unwrap();
    while coord.rounds_run() < ROUNDS {
        coord.round_stored().unwrap();
    }
    coord.flush_trace().unwrap();
    let contents = CampaignStore::read(dir).unwrap();
    let _ = std::fs::remove_dir_all(dir);
    contents
}

#[test]
fn traced_campaign_journals_bit_identically_and_replays() {
    let base = std::env::temp_dir().join("fedzero_obs_trace_golden");
    let trace_path = base.join("campaign.trace.jsonl");
    let _ = std::fs::create_dir_all(&base);
    let plain = campaign(&base.join("untraced"), None);
    let traced = campaign(&base.join("traced"), Some(&trace_path));

    // Per-field bit equality, timings excluded (they are wall-clock and
    // excluded from digests by construction).
    assert_eq!(plain.entries.len(), ROUNDS);
    assert_eq!(traced.entries.len(), ROUNDS);
    for (a, b) in plain.entries.iter().zip(&traced.entries) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.solver, b.solver, "round {}", a.round);
        assert_eq!(a.digest, b.digest, "round {}", a.round);
        assert_eq!(a.rng_after, b.rng_after, "round {}", a.round);
        assert_eq!(a.row.loss.to_bits(), b.row.loss.to_bits());
        assert_eq!(a.row.energy_j.to_bits(), b.row.energy_j.to_bits());
        assert_eq!(a.row.participants, b.row.participants);
        assert_eq!(a.row.tasks, b.row.tasks);
        assert!(
            !b.to_json().to_string().contains("obs_"),
            "journal lines must not carry telemetry fields"
        );
    }
    assert_eq!(
        campaign_digest(&plain.entries),
        campaign_digest(&traced.entries),
        "tracing must not perturb the campaign digest"
    );

    // Both journals replay (restore re-executes and verifies every
    // entry; reaching Ok is the audit passing) to the same round count.
    for contents in [&plain, &traced] {
        let c = Coordinator::restore(
            cfg(),
            &contents.init_snapshot,
            &contents.entries,
            SimBackend::new(),
            None,
        )
        .unwrap();
        assert_eq!(c.rounds_run(), ROUNDS);
    }

    // The trace itself: valid JSONL, every duration span balanced in
    // file order per (name, lane), and the store-side spans present.
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let _ = std::fs::remove_dir_all(&base);
    assert!(!text.is_empty(), "traced run must emit spans");
    let mut open: Vec<(String, String)> = Vec::new();
    let mut names: std::collections::BTreeSet<String> = Default::default();
    for line in text.lines() {
        let v = Json::parse(line).expect("trace lines are valid JSON");
        let ph = v.req("ph").unwrap().as_str().unwrap().to_string();
        let name = v.req("name").unwrap().as_str().unwrap().to_string();
        let tid = v.req("tid").unwrap().as_f64().unwrap().to_string();
        names.insert(name.clone());
        match ph.as_str() {
            "B" => open.push((name, tid)),
            "E" => {
                assert_eq!(open.pop().expect("E without B"), (name, tid))
            }
            "i" => {}
            other => panic!("unexpected phase {other}"),
        }
    }
    assert!(open.is_empty(), "unbalanced spans: {open:?}");
    for expected in ["round", "journal_append", "snapshot", "solve"] {
        assert!(names.contains(expected), "missing span '{expected}'");
    }
}
