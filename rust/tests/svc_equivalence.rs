//! Service-layer equivalence and safety properties.
//!
//! Part 1 — registry interleavings: over thousands of random
//! heartbeat/expiry/rejoin/fetch/report interleavings, the participant
//! registry never loses an accepted report, never accepts the same
//! (device, round) report twice (no double-counted energy), and never
//! has an expired or unscheduled participant in `Selected`/`Training`.
//!
//! Part 2 — store-level digest equivalence: a campaign served over the
//! loopback transport (with connection churn) journals the *same bytes*
//! as the in-process `SimBackend` reference on the same fleet, and a
//! loopback campaign killed mid-run resumes to the exact clean-run
//! digest even with hard stragglers forcing partial rounds.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use fedzero::coordinator::{
    BackendState, Coordinator, CoordinatorConfig, KnobSet, ManagedDevice,
    RoundBackend, SimBackend,
};
use fedzero::fl::dynamics::DynamicsConfig;
use fedzero::sched::costs::CostFn;
use fedzero::store::journal::{campaign_digest, JournalEntry};
use fedzero::store::{get, snapshot as snap, CampaignStore};
use fedzero::svc::{
    loopback_service, LoopbackService, ParticipantPhase, ParticipantRegistry,
    ReportVerdict, ServiceConfig, SimClientsConfig,
};
use fedzero::testkit::{ensure, forall, Config, Gen, PropResult};
use fedzero::util::json::Json;
use fedzero::util::rng::Rng;

// ---------------------------------------------------------------------------
// Part 1: registry interleaving properties
// ---------------------------------------------------------------------------

const DEVICES: usize = 5;
const EXPIRY: u64 = 3;

/// One step of a random client/coordinator interleaving. `Join` doubles
/// as churn: a device that already had a binding comes back under a new
/// client id, superseding the old one.
#[derive(Clone, Debug)]
enum Op {
    /// Advance the logical clock one tick.
    Advance,
    /// Rendezvous a (possibly new) client id for the device.
    Join(usize),
    /// Heartbeat from the device's current client.
    Heartbeat(usize),
    /// Heartbeat from a superseded client id — must be refused.
    StaleHeartbeat(usize),
    /// FetchSlice for the served round.
    Fetch(usize),
    /// Report for the served round.
    Report(usize),
    /// Report naming a round the service is not serving.
    StaleReport(usize),
    /// Heartbeat + fetch + report in sequence (the happy path, so
    /// accepted reports are common in random runs).
    Complete(usize),
    /// Close the round and open the next with the bitmask's selection.
    NextRound(u8),
}

struct OpsGen;

impl Gen<Vec<Op>> for OpsGen {
    fn generate(&self, rng: &mut Rng) -> Vec<Op> {
        let n = 20 + rng.index(60);
        (0..n)
            .map(|_| {
                let d = rng.index(DEVICES);
                match rng.index(12) {
                    0 | 1 => Op::Advance,
                    2 | 3 => Op::Join(d),
                    4 => Op::Heartbeat(d),
                    5 => Op::StaleHeartbeat(d),
                    6 => Op::Fetch(d),
                    7 => Op::Report(d),
                    8 => Op::StaleReport(d),
                    9 | 10 => Op::Complete(d),
                    _ => Op::NextRound(rng.below(32) as u8),
                }
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<Op>) -> Vec<Vec<Op>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[v.len() / 2..].to_vec());
            for i in 0..v.len().min(8) {
                let mut w = v.clone();
                w.remove(i);
                out.push(w);
            }
        }
        out
    }
}

/// Phase sanity after any op: `Selected`/`Training`/`Done` only ever
/// hold for devices the served round actually scheduled.
fn phases_respect_selection(
    reg: &ParticipantRegistry,
    selection: &BTreeSet<usize>,
) -> PropResult {
    for (d, p) in reg.participants() {
        if p.phase != ParticipantPhase::Standby {
            ensure(
                selection.contains(&d),
                format!("device {d} is {:?} but was never scheduled", p.phase),
            )?;
        }
    }
    Ok(())
}

/// Heartbeat from the device's current binding; a `Selected` ack must
/// name a scheduled device.
fn try_heartbeat(
    reg: &mut ParticipantRegistry,
    client: u64,
    d: usize,
    round: usize,
    selection: &BTreeSet<usize>,
) -> PropResult {
    if let Some((phase, r)) = reg.heartbeat(client, d) {
        ensure(r == round, "heartbeat ack named a stale round")?;
        if phase == ParticipantPhase::Selected {
            ensure(
                selection.contains(&d),
                format!("device {d} selected but not scheduled"),
            )?;
        }
    }
    Ok(())
}

fn try_fetch(
    reg: &mut ParticipantRegistry,
    client: u64,
    d: usize,
    round: usize,
    selection: &BTreeSet<usize>,
) -> PropResult {
    if reg.fetch(client, d, round) {
        ensure(
            selection.contains(&d),
            format!("device {d} training but not scheduled"),
        )?;
    }
    Ok(())
}

fn try_report(
    reg: &mut ParticipantRegistry,
    client: u64,
    d: usize,
    round: usize,
    selection: &BTreeSet<usize>,
    accepted: &mut BTreeSet<(usize, usize)>,
    accepted_this_round: &mut usize,
) -> PropResult {
    if reg.report(client, d, round) == ReportVerdict::Accepted {
        ensure(
            accepted.insert((d, round)),
            format!("device {d} report double-accepted in round {round}"),
        )?;
        ensure(
            selection.contains(&d),
            format!("unscheduled device {d} reported"),
        )?;
        *accepted_this_round += 1;
    }
    Ok(())
}

fn run_interleaving(ops: &[Op]) -> PropResult {
    let mut reg = ParticipantRegistry::new(EXPIRY);
    let mut next_client: u64 = 1;
    // Our model of the world: current binding per device, superseded
    // ids, and every (device, round) report the registry accepted.
    let mut cur: BTreeMap<usize, u64> = BTreeMap::new();
    let mut old: BTreeMap<usize, u64> = BTreeMap::new();
    let mut accepted: BTreeSet<(usize, usize)> = BTreeSet::new();
    let mut accepted_this_round = 0usize;
    let mut round = 0usize;
    let mut selection: BTreeSet<usize> = (0..DEVICES).collect();
    let sel_vec: Vec<usize> = selection.iter().copied().collect();
    reg.begin_round(round, &sel_vec);

    for op in ops {
        match *op {
            Op::Advance => reg.advance(),
            Op::Join(d) => {
                let client = next_client;
                next_client += 1;
                if let Some(prev) = cur.insert(d, client) {
                    old.insert(d, prev);
                }
                reg.rendezvous(client, d);
            }
            Op::StaleHeartbeat(d) => {
                if let Some(&c) = old.get(&d) {
                    ensure(
                        reg.heartbeat(c, d).is_none(),
                        format!("superseded client {c} of device {d} was heard"),
                    )?;
                }
            }
            Op::StaleReport(d) => {
                if let Some(&c) = cur.get(&d) {
                    let v = reg.report(c, d, round + 1);
                    ensure(
                        v != ReportVerdict::Accepted,
                        format!("device {d} stale-round report accepted"),
                    )?;
                }
            }
            Op::Heartbeat(d) => {
                if let Some(&c) = cur.get(&d) {
                    try_heartbeat(&mut reg, c, d, round, &selection)?;
                }
            }
            Op::Fetch(d) => {
                if let Some(&c) = cur.get(&d) {
                    try_fetch(&mut reg, c, d, round, &selection)?;
                }
            }
            Op::Report(d) => {
                if let Some(&c) = cur.get(&d) {
                    try_report(
                        &mut reg,
                        c,
                        d,
                        round,
                        &selection,
                        &mut accepted,
                        &mut accepted_this_round,
                    )?;
                }
            }
            Op::Complete(d) => {
                if let Some(&c) = cur.get(&d) {
                    try_heartbeat(&mut reg, c, d, round, &selection)?;
                    try_fetch(&mut reg, c, d, round, &selection)?;
                    try_report(
                        &mut reg,
                        c,
                        d,
                        round,
                        &selection,
                        &mut accepted,
                        &mut accepted_this_round,
                    )?;
                }
            }
            Op::NextRound(mask) => {
                let end = reg.finish_round();
                ensure(
                    end.reported == accepted_this_round,
                    format!(
                        "round {round}: {} accepted reports but {} counted at close",
                        accepted_this_round, end.reported
                    ),
                )?;
                accepted_this_round = 0;
                round += 1;
                selection = (0..DEVICES).filter(|d| mask & (1 << d) != 0).collect();
                let sel_vec: Vec<usize> = selection.iter().copied().collect();
                reg.begin_round(round, &sel_vec);
                for (d, p) in reg.participants() {
                    ensure(
                        reg.clock().saturating_sub(p.last_seen) <= EXPIRY,
                        format!("expired device {d} survived the round boundary"),
                    )?;
                }
            }
        }
        phases_respect_selection(&reg, &selection)?;
    }
    Ok(())
}

#[test]
fn registry_interleavings_preserve_report_invariants() {
    let cfg = Config { cases: 1500, seed: 0x5EC, max_shrink: 200 };
    forall(&cfg, &OpsGen, |ops| run_interleaving(ops));
}

// ---------------------------------------------------------------------------
// Part 2: store-level digest equivalence
// ---------------------------------------------------------------------------

const ROUNDS: usize = 10;
const SNAPSHOT_EVERY: usize = 4;
const FLEET_SIZE: usize = 6;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fedzero_svc_equiv").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Six devices across the cost families the slice codec must carry
/// exactly: affine, quadratic, tabulated, power-law, plus a duplicated
/// spec so class deduplication is exercised end to end.
fn fleet() -> Vec<ManagedDevice> {
    let affine = CostFn::Affine { fixed: 0.0, per_task: 1.0 };
    let quad = CostFn::Quadratic { fixed: 0.5, a: 0.25, b: 0.5 };
    let table = CostFn::from_table(&[(0, 0.0), (1, 1.5), (2, 2.5), (3, 4.5), (4, 5.0)]);
    let sqrtish = CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.6 };
    vec![
        ManagedDevice::abstract_resource(0, affine.clone(), 0, 4),
        ManagedDevice::abstract_resource(1, affine, 0, 4),
        ManagedDevice::abstract_resource(2, quad, 0, 5),
        ManagedDevice::abstract_resource(3, table, 1, 4),
        ManagedDevice::abstract_resource(4, sqrtish.clone(), 0, 6),
        ManagedDevice::abstract_resource(5, sqrtish, 0, 6),
    ]
}

fn cfg_for(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        rounds: ROUNDS,
        tasks_per_round: 8,
        algo: "auto".to_string(),
        participation: 0.8,
        max_share: 1.0,
        seed,
        ..CoordinatorConfig::default()
    }
}

fn sim_cfg(seed: u64, churn: u32, miss: u32) -> SimClientsConfig {
    SimClientsConfig {
        seed,
        churn_permille: churn,
        miss_permille: miss,
        ..SimClientsConfig::default()
    }
}

fn service(seed: u64, churn: u32, miss: u32) -> LoopbackService {
    loopback_service(
        ServiceConfig::default(),
        sim_cfg(seed, churn, miss),
        (0..FLEET_SIZE).collect(),
    )
}

fn new_stored<B: RoundBackend + BackendState>(
    seed: u64,
    dir: &Path,
    backend: B,
) -> Coordinator<B> {
    let cfg = cfg_for(seed);
    let mut c = Coordinator::new(cfg.clone(), fleet(), backend).unwrap();
    KnobSet {
        dynamics: Some(DynamicsConfig::mobile(FLEET_SIZE)),
        ..KnobSet::default()
    }
    .apply_to(&mut c)
    .unwrap();
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(SNAPSHOT_EVERY as f64)),
        ("cfg", snap::cfg_to_json(&cfg)),
    ]);
    let store = CampaignStore::create(dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
    c
}

fn drive<B: RoundBackend + BackendState>(c: &mut Coordinator<B>, upto: usize) {
    while c.rounds_run() < upto {
        let _ = c.round_stored();
    }
}

fn assert_entries_equal(ctx: &str, a: &[JournalEntry], b: &[JournalEntry]) {
    assert_eq!(a.len(), b.len(), "{ctx}: campaign length");
    for (ea, eb) in a.iter().zip(b) {
        let at = format!("{ctx}, round {}", ea.round);
        assert_eq!(ea.round, eb.round, "{at}: round index");
        assert_eq!(ea.solver, eb.solver, "{at}: effective solver");
        assert_eq!(ea.digest, eb.digest, "{at}: instance/schedule digest");
        assert_eq!(ea.rng_after, eb.rng_after, "{at}: RNG state");
        assert_eq!(
            ea.row.energy_j.to_bits(),
            eb.row.energy_j.to_bits(),
            "{at}: energy"
        );
        assert_eq!(ea.row.participants, eb.row.participants, "{at}: participants");
        assert_eq!(ea.row.tasks, eb.row.tasks, "{at}: tasks");
    }
    assert_eq!(campaign_digest(a), campaign_digest(b), "{ctx}: campaign digest");
}

/// The tentpole contract: a campaign served over the wire — churn and
/// all — journals exactly what the in-process reference journals.
#[test]
fn loopback_campaign_digest_matches_in_process_reference() {
    let seed = 0xD1;
    let sim_dir = scratch("reference");
    let svc_dir = scratch("loopback");

    let mut sim = new_stored(seed, &sim_dir, SimBackend::new());
    drive(&mut sim, ROUNDS);
    let reference = CampaignStore::read(&sim_dir).unwrap().entries;

    let mut svc = new_stored(seed, &svc_dir, service(seed, 400, 0));
    drive(&mut svc, ROUNDS);
    // The equivalence must hold *despite* real protocol traffic: clients
    // actually churned and rejoined along the way.
    assert!(
        svc.backend().stats().counter("svc_rejoins") > 0,
        "churn never fired — the equivalence test lost its teeth"
    );
    assert_eq!(svc.backend().stats().counter("svc_stragglers"), 0);
    let served = CampaignStore::read(&svc_dir).unwrap().entries;

    assert_entries_equal("loopback vs in-process", &reference, &served);
    let _ = std::fs::remove_dir_all(&sim_dir);
    let _ = std::fs::remove_dir_all(&svc_dir);
}

/// Kill a loopback campaign mid-run (with churn *and* hard stragglers
/// forcing partial rounds) and resume it over a cold service — fresh
/// registry, fresh tick clock, clients re-rendezvousing from scratch.
/// The fleet's memoryless behavior makes the resumed journal
/// bit-identical to the uninterrupted one.
#[test]
fn killed_loopback_campaign_resumes_to_clean_digest() {
    let seed = 0xD2;
    let (churn, miss) = (400, 150);
    let clean_dir = scratch("kill_clean");
    let crash_dir = scratch("kill_crash");

    let mut clean = new_stored(seed, &clean_dir, service(seed, churn, miss));
    drive(&mut clean, ROUNDS);
    assert!(
        clean.backend().stats().counter("svc_stragglers") > 0,
        "no straggler fired — partial-round resume went untested"
    );
    let clean_entries = CampaignStore::read(&clean_dir).unwrap().entries;

    {
        let mut c = new_stored(seed, &crash_dir, service(seed, churn, miss));
        drive(&mut c, 5);
        // Dropping the coordinator IS the crash: the journal is fsync'd
        // per round, nothing else is flushed.
    }
    let (store, contents) = CampaignStore::resume(&crash_dir).unwrap();
    let cfg = snap::cfg_from_json(get(&contents.meta, "cfg").unwrap()).unwrap();
    let mut resumed = Coordinator::restore(
        cfg,
        &contents.snapshot,
        &contents.entries,
        service(seed, churn, miss),
        None,
    )
    .unwrap();
    resumed.attach_store(store).unwrap();
    drive(&mut resumed, ROUNDS);
    let resumed_entries = CampaignStore::read(&crash_dir).unwrap().entries;

    assert_entries_equal("kill/resume", &clean_entries, &resumed_entries);
    let _ = std::fs::remove_dir_all(&clean_dir);
    let _ = std::fs::remove_dir_all(&crash_dir);
}
