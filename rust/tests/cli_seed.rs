//! End-to-end CLI reproducibility and error-path checks, driving the
//! compiled `fedzero` binary:
//!
//! * `--seed` threads through fleet sampling and the solver RNG, so
//!   `random`-baseline runs replay bit-for-bit from the command line;
//! * `--algo` errors and the `solvers` subcommand print each solver's
//!   Table 2 applicability, not just the registry names.

use std::process::{Command, Output};

fn fedzero(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_fedzero"))
        .args(args)
        .output()
        .expect("failed to spawn the fedzero binary")
}

fn stdout_ok(args: &[&str]) -> String {
    let out = fedzero(args);
    assert!(
        out.status.success(),
        "fedzero {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8(out.stdout).unwrap()
}

/// The schedule JSON minus its (nondeterministic) solve-time field.
fn stable_schedule_part(json: &str) -> (String, String) {
    let energy = json
        .split("\"energy_j\":")
        .nth(1)
        .expect("energy_j in JSON output")
        .split(',')
        .next()
        .unwrap()
        .to_string();
    let assignments = json
        .split("\"assignments\":")
        .nth(1)
        .expect("assignments in JSON output")
        .to_string();
    (energy, assignments)
}

#[test]
fn random_baseline_is_reproducible_per_seed() {
    let args = [
        "schedule", "--algo", "random", "--regime", "arbitrary", "--tasks",
        "60", "--devices", "8", "--seed", "11", "--json",
    ];
    let a = stable_schedule_part(&stdout_ok(&args));
    let b = stable_schedule_part(&stdout_ok(&args));
    assert_eq!(a, b, "same seed must reproduce the same random schedule");

    let mut other = args;
    other[10] = "12";
    let c = stable_schedule_part(&stdout_ok(&other));
    assert_ne!(a, c, "different seeds must explore different runs");
}

#[test]
fn deterministic_solver_is_seed_stable_too() {
    let args = [
        "schedule", "--algo", "auto", "--regime", "increasing", "--tasks",
        "40", "--devices", "6", "--seed", "3", "--json",
    ];
    assert_eq!(
        stable_schedule_part(&stdout_ok(&args)),
        stable_schedule_part(&stdout_ok(&args))
    );
}

#[test]
fn solvers_subcommand_prints_table2_applicability() {
    let out = stdout_ok(&["solvers"]);
    assert!(out.contains("mc2mkp"), "{out}");
    assert!(out.contains("dec∞"), "{out}");
    assert!(out.contains("applicability:"), "{out}");
    assert!(out.contains("marin[inc,con]"), "{out}");
    assert!(out.contains("auto dispatch"), "{out}");
}

#[test]
fn unknown_algo_error_lists_applicability() {
    let out = fedzero(&["schedule", "--algo", "not-a-solver"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("not-a-solver"), "{err}");
    assert!(err.contains("mc2mkp[arb,inc,con,dec,dec∞]"), "{err}");
    assert!(err.contains("olar[—]"), "{err}");
}
