//! Failure injection: malformed instances, corrupted artifacts, degenerate
//! fleets, and configuration errors must fail loudly and cleanly (typed
//! errors, no panics) — including faults that strike **mid-pipeline**,
//! while the coordinator has a speculative next round in flight: the
//! speculation must never reach the journal, and the campaign must stay
//! resumable.

use std::path::Path;

use fedzero::config::TrainConfig;
use fedzero::coordinator::{
    Coordinator, CoordinatorConfig, DeviceOutcome, ManagedDevice, PipelineConfig,
    RoundBackend, RoundPlan, SimBackend,
};
use fedzero::energy::battery::Battery;
use fedzero::energy::power::{Behavior, PowerModel};
use fedzero::error::FedError;
use fedzero::runtime::Manifest;
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::{marco, mardec, mardecun, marin, mc2mkp};
use fedzero::store::journal::{read_journal, ABORTED_SOLVER};
use fedzero::store::{snapshot as snap, CampaignStore};
use fedzero::util::json::Json;
use fedzero::Result;

fn affine() -> CostFn {
    CostFn::Affine { fixed: 0.0, per_task: 1.0 }
}

#[test]
fn solvers_reject_invalid_instances() {
    // ΣU < T — no feasible schedule.
    let bad = Instance {
        tasks: 10,
        lower: vec![0, 0],
        upper: vec![3, 3],
        costs: vec![affine(), affine()],
    };
    assert!(matches!(mc2mkp::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(marin::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(marco::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(mardecun::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(mardec::solve(&bad), Err(FedError::InvalidInstance(_))));
}

#[test]
fn solvers_reject_lower_above_upper() {
    let bad = Instance {
        tasks: 5,
        lower: vec![4, 0],
        upper: vec![2, 8],
        costs: vec![affine(), affine()],
    };
    for result in [mc2mkp::solve(&bad), marin::solve(&bad), marco::solve(&bad)] {
        assert!(matches!(result, Err(FedError::InvalidInstance(_))));
    }
}

#[test]
fn mardecun_refuses_limited_instances() {
    let inst = Instance::new(
        10,
        vec![0, 0],
        vec![4, 10],
        vec![
            CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
            CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.5 },
        ],
    )
    .unwrap();
    assert!(matches!(
        mardecun::solve(&inst),
        Err(FedError::ScenarioMismatch(_))
    ));
}

#[test]
fn dead_battery_device_contributes_zero_capacity() {
    let power = PowerModel {
        idle_w: 0.1,
        busy_w: 2.0,
        batch_latency_s: 0.5,
        behavior: Behavior::Linear,
        curvature: 0.0,
    };
    let dead = Battery { capacity_wh: 10.0, level: 0.0, round_budget_frac: 0.1 };
    assert_eq!(dead.max_batches(&power), 0);
}

#[test]
fn config_rejections() {
    for toml in [
        "devices = 0",
        "tasks_per_round = 0",
        "participation = 1.5",
        "participation = 0.0",
        "dirichlet_alpha = 0.0",
        "max_share = 0.0",
        "max_share = 1.5",
        "workers = 0",
        "policy = \"nope\"",
    ] {
        assert!(
            TrainConfig::from_toml(toml).is_err(),
            "config '{toml}' should be rejected"
        );
    }
}

#[test]
fn corrupted_manifest_variants() {
    let dir = std::env::temp_dir().join("fedzero_failinj");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // Not JSON at all.
    std::fs::write(dir.join("manifest.json"), "garbage{{").unwrap();
    assert!(Manifest::load(&dir).is_err());

    // Wrong version.
    std::fs::write(dir.join("manifest.json"), r#"{"version": 9, "models": {}}"#).unwrap();
    assert!(matches!(Manifest::load(&dir), Err(FedError::Artifact(_))));

    // Missing models key.
    std::fs::write(dir.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());

    // Model with inconsistent shapes.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "models": {"m": {
            "family": "mlp", "classes": 2,
            "train_hlo": "a", "eval_hlo": "b", "params_file": "c",
            "param_shapes": [[2,2]], "param_count": 5, "n_param_tensors": 1,
            "batch": 1, "lr": 0.1,
            "input_shape": [1,2], "input_dtype": "f32",
            "label_shape": [1], "label_dtype": "s32"}}}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("param_shapes sum"));

    // Truncated params file.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "models": {"m": {
            "family": "mlp", "classes": 2,
            "train_hlo": "a", "eval_hlo": "b", "params_file": "m_params.bin",
            "param_shapes": [[2,2]], "param_count": 4, "n_param_tensors": 1,
            "batch": 1, "lr": 0.1,
            "input_shape": [1,2], "input_dtype": "f32",
            "label_shape": [1], "label_dtype": "s32"}}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("m_params.bin"), [0u8; 7]).unwrap(); // needs 16
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.model("m").unwrap();
    assert!(manifest.load_params(spec).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_artifacts_dir_guides_user() {
    let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn tabulated_cost_domain_violation_panics_not_corrupts() {
    let c = CostFn::from_table(&[(0, 0.0), (1, 1.0)]);
    let result = std::panic::catch_unwind(|| c.eval(5));
    assert!(result.is_err());
}

#[test]
fn zero_capacity_instance_rejected_at_build() {
    assert!(Instance::new(1, vec![0], vec![0], vec![affine()]).is_err());
}

// ---- mid-pipeline faults ----------------------------------------------

fn pipeline_fleet() -> Vec<ManagedDevice> {
    let inst = Instance::paper_example(5);
    (0..inst.n())
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                inst.costs[i].clone(),
                inst.lower[i],
                inst.upper[i],
            )
        })
        .collect()
}

fn pipeline_cfg(rounds: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        rounds,
        tasks_per_round: 5,
        algo: "mc2mkp".into(),
        max_share: 1.0,
        pipeline: PipelineConfig::on(),
        ..CoordinatorConfig::default()
    }
}

fn attach_fresh_store(
    c: &mut Coordinator<impl RoundBackend + fedzero::coordinator::BackendState>,
    dir: &Path,
) {
    let meta = Json::obj(vec![
        ("snapshot_every", Json::Num(2.0)),
        ("cfg", snap::cfg_to_json(c.cfg())),
    ]);
    let store = CampaignStore::create(dir, meta, c.snapshot_json()).unwrap();
    c.attach_store(store).unwrap();
}

/// Backend that fails its training leg on one specific round — the
/// failure lands in `finish_train`, i.e. *after* the coordinator has
/// speculatively scheduled the next round in the overlap window.
struct FailFinish {
    inner: SimBackend,
    fail_round: usize,
}

impl RoundBackend for FailFinish {
    fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        if plan.round == self.fail_round {
            return Err(FedError::Fl("injected training failure".into()));
        }
        self.inner.train(plan)
    }
    fn begin_train(&mut self, plan: &RoundPlan) -> Result<bool> {
        // The window opens normally (the sim leg starts); only the
        // collection side fails — i.e. the coordinator has already
        // speculated by the time the error lands.
        self.inner.begin_train(plan)
    }
    fn finish_train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
        if plan.round == self.fail_round {
            return Err(FedError::Fl("injected training failure".into()));
        }
        self.inner.finish_train(plan)
    }
    fn aggregate(&mut self) -> Result<()> {
        self.inner.aggregate()
    }
    fn evaluate(&mut self) -> Result<f64> {
        self.inner.evaluate()
    }
}

impl fedzero::coordinator::BackendState for FailFinish {
    fn save_state(&self) -> Json {
        self.inner.save_state()
    }
    fn load_state(&mut self, state: &Json) -> Result<()> {
        self.inner.load_state(state)
    }
}

/// Backend error while a speculation is in flight: round `r` fails after
/// the overlap window has already prepared round `r + 1`. The journal
/// must show `r` as aborted, stay contiguous, and never contain the
/// speculative round's schedule out of order — and the campaign keeps
/// driving afterwards.
#[test]
fn backend_error_during_overlapped_scheduling_never_journals_the_speculation() {
    let dir = std::env::temp_dir().join("fedzero_failinj_pipeline_backend");
    let _ = std::fs::remove_dir_all(&dir);
    let rounds = 5;
    let mut c = Coordinator::new(
        pipeline_cfg(rounds),
        pipeline_fleet(),
        FailFinish { inner: SimBackend::new(), fail_round: 2 },
    )
    .unwrap();
    attach_fresh_store(&mut c, &dir);
    let mut errors = 0usize;
    while c.rounds_run() < rounds {
        if c.round_stored().is_err() {
            errors += 1;
        }
    }
    assert_eq!(errors, 1, "exactly the injected round fails");
    // The journal is the proof: contiguous rounds 0..5, round 2 aborted,
    // rounds 3 and 4 normal — the speculation prepared during round 2's
    // overlap window never became a journal line of its own.
    let entries = read_journal(&dir.join("journal.jsonl")).unwrap();
    assert_eq!(entries.len(), rounds);
    for (i, e) in entries.iter().enumerate() {
        assert_eq!(e.round, i, "journal must stay contiguous");
    }
    assert_eq!(entries[2].solver, ABORTED_SOLVER);
    assert_eq!(entries[2].digest, 0, "aborted rounds carry no schedule digest");
    assert_eq!(entries[3].solver, "mc2mkp", "campaign recovers after the abort");
    assert_eq!(entries[4].solver, "mc2mkp");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `begin_train` failure: the round aborts before the overlap window
/// even opens. No speculation may be created for it, and the abort is
/// journaled like any other.
#[test]
fn begin_train_error_aborts_before_the_overlap_window() {
    struct FailBegin {
        inner: SimBackend,
    }
    impl RoundBackend for FailBegin {
        fn train(&mut self, plan: &RoundPlan) -> Result<Vec<DeviceOutcome>> {
            self.inner.train(plan)
        }
        fn begin_train(&mut self, _plan: &RoundPlan) -> Result<bool> {
            Err(FedError::Fl("injected begin_train failure".into()))
        }
        fn aggregate(&mut self) -> Result<()> {
            self.inner.aggregate()
        }
        fn evaluate(&mut self) -> Result<f64> {
            self.inner.evaluate()
        }
    }
    let mut c = Coordinator::new(
        pipeline_cfg(3),
        pipeline_fleet(),
        FailBegin { inner: SimBackend::new() },
    )
    .unwrap();
    let err = c.round().unwrap_err().to_string();
    assert!(err.contains("begin_train"), "{err}");
    assert_eq!(
        c.metrics().counter("pipeline_speculations"),
        0,
        "the overlap window never opened"
    );
    assert_eq!(c.metrics().counter("aborted_rounds"), 1);
}

/// Store fault while a speculation is in flight: make the store
/// directory unwritable so the next due snapshot write fails mid-flight.
/// The error must surface, the journal must hold exactly the committed
/// rounds (never the speculative one), and once the directory is healed
/// the campaign must finish on the serial clean run's exact digests.
#[cfg(unix)]
#[test]
fn store_write_failure_with_speculation_in_flight_is_contained() {
    use std::os::unix::fs::PermissionsExt;
    use fedzero::store::journal::campaign_digest;

    let perms = |dir: &Path, mode: u32| {
        std::fs::set_permissions(dir, std::fs::Permissions::from_mode(mode)).unwrap();
    };
    let rounds = 6;

    // Reference: a serial, unfaulted campaign.
    let clean_dir = std::env::temp_dir().join("fedzero_failinj_store_clean");
    let _ = std::fs::remove_dir_all(&clean_dir);
    let serial_cfg = CoordinatorConfig {
        pipeline: PipelineConfig::off(),
        ..pipeline_cfg(rounds)
    };
    let mut clean =
        Coordinator::new(serial_cfg, pipeline_fleet(), SimBackend::new()).unwrap();
    attach_fresh_store(&mut clean, &clean_dir);
    while clean.rounds_run() < rounds {
        clean.round_stored().unwrap();
    }
    let clean_entries = read_journal(&clean_dir.join("journal.jsonl")).unwrap();

    // Faulted: pipelined, directory turned read-only after round 0 so the
    // snapshot due after round 1 (snapshot_every = 2) cannot be written.
    let dir = std::env::temp_dir().join("fedzero_failinj_store_fault");
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = Coordinator::new(
        pipeline_cfg(rounds),
        pipeline_fleet(),
        SimBackend::new(),
    )
    .unwrap();
    attach_fresh_store(&mut c, &dir);
    c.round_stored().unwrap();
    perms(&dir, 0o555);
    let second = c.round_stored();
    perms(&dir, 0o755);
    match second {
        Err(e) => {
            // The snapshot write failed; the round itself had already
            // committed (journal-first), and the speculation for round 2
            // stayed in memory. The journal must hold exactly rounds 0–1.
            let entries = read_journal(&dir.join("journal.jsonl")).unwrap();
            assert_eq!(entries.len(), 2, "rounds 0 and 1 committed: {e}");
            // Healed: the campaign finishes and matches the serial run.
            while c.rounds_run() < rounds {
                c.round_stored().unwrap();
            }
            let entries = read_journal(&dir.join("journal.jsonl")).unwrap();
            assert_eq!(campaign_digest(&entries), campaign_digest(&clean_entries));
        }
        Ok(_) => {
            // Running as root (read-only dirs don't bind): nothing to
            // assert about the fault path, but the campaign must still
            // match the serial reference end-to-end.
            eprintln!("read-only dir did not fault (root?); checking equality only");
            while c.rounds_run() < rounds {
                c.round_stored().unwrap();
            }
            let entries = read_journal(&dir.join("journal.jsonl")).unwrap();
            assert_eq!(campaign_digest(&entries), campaign_digest(&clean_entries));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&clean_dir);
}
