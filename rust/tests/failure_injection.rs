//! Failure injection: malformed instances, corrupted artifacts, degenerate
//! fleets, and configuration errors must fail loudly and cleanly (typed
//! errors, no panics).

use std::path::Path;

use fedzero::config::TrainConfig;
use fedzero::energy::battery::Battery;
use fedzero::energy::power::{Behavior, PowerModel};
use fedzero::error::FedError;
use fedzero::runtime::Manifest;
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::{marco, mardec, mardecun, marin, mc2mkp};

fn affine() -> CostFn {
    CostFn::Affine { fixed: 0.0, per_task: 1.0 }
}

#[test]
fn solvers_reject_invalid_instances() {
    // ΣU < T — no feasible schedule.
    let bad = Instance {
        tasks: 10,
        lower: vec![0, 0],
        upper: vec![3, 3],
        costs: vec![affine(), affine()],
    };
    assert!(matches!(mc2mkp::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(marin::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(marco::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(mardecun::solve(&bad), Err(FedError::InvalidInstance(_))));
    assert!(matches!(mardec::solve(&bad), Err(FedError::InvalidInstance(_))));
}

#[test]
fn solvers_reject_lower_above_upper() {
    let bad = Instance {
        tasks: 5,
        lower: vec![4, 0],
        upper: vec![2, 8],
        costs: vec![affine(), affine()],
    };
    for result in [mc2mkp::solve(&bad), marin::solve(&bad), marco::solve(&bad)] {
        assert!(matches!(result, Err(FedError::InvalidInstance(_))));
    }
}

#[test]
fn mardecun_refuses_limited_instances() {
    let inst = Instance::new(
        10,
        vec![0, 0],
        vec![4, 10],
        vec![
            CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
            CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.5 },
        ],
    )
    .unwrap();
    assert!(matches!(
        mardecun::solve(&inst),
        Err(FedError::ScenarioMismatch(_))
    ));
}

#[test]
fn dead_battery_device_contributes_zero_capacity() {
    let power = PowerModel {
        idle_w: 0.1,
        busy_w: 2.0,
        batch_latency_s: 0.5,
        behavior: Behavior::Linear,
        curvature: 0.0,
    };
    let dead = Battery { capacity_wh: 10.0, level: 0.0, round_budget_frac: 0.1 };
    assert_eq!(dead.max_batches(&power), 0);
}

#[test]
fn config_rejections() {
    for toml in [
        "devices = 0",
        "tasks_per_round = 0",
        "participation = 1.5",
        "participation = 0.0",
        "dirichlet_alpha = 0.0",
        "max_share = 0.0",
        "max_share = 1.5",
        "workers = 0",
        "policy = \"nope\"",
    ] {
        assert!(
            TrainConfig::from_toml(toml).is_err(),
            "config '{toml}' should be rejected"
        );
    }
}

#[test]
fn corrupted_manifest_variants() {
    let dir = std::env::temp_dir().join("fedzero_failinj");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // Not JSON at all.
    std::fs::write(dir.join("manifest.json"), "garbage{{").unwrap();
    assert!(Manifest::load(&dir).is_err());

    // Wrong version.
    std::fs::write(dir.join("manifest.json"), r#"{"version": 9, "models": {}}"#).unwrap();
    assert!(matches!(Manifest::load(&dir), Err(FedError::Artifact(_))));

    // Missing models key.
    std::fs::write(dir.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err());

    // Model with inconsistent shapes.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "models": {"m": {
            "family": "mlp", "classes": 2,
            "train_hlo": "a", "eval_hlo": "b", "params_file": "c",
            "param_shapes": [[2,2]], "param_count": 5, "n_param_tensors": 1,
            "batch": 1, "lr": 0.1,
            "input_shape": [1,2], "input_dtype": "f32",
            "label_shape": [1], "label_dtype": "s32"}}}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("param_shapes sum"));

    // Truncated params file.
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "models": {"m": {
            "family": "mlp", "classes": 2,
            "train_hlo": "a", "eval_hlo": "b", "params_file": "m_params.bin",
            "param_shapes": [[2,2]], "param_count": 4, "n_param_tensors": 1,
            "batch": 1, "lr": 0.1,
            "input_shape": [1,2], "input_dtype": "f32",
            "label_shape": [1], "label_dtype": "s32"}}}"#,
    )
    .unwrap();
    std::fs::write(dir.join("m_params.bin"), [0u8; 7]).unwrap(); // needs 16
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.model("m").unwrap();
    assert!(manifest.load_params(spec).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_artifacts_dir_guides_user() {
    let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn tabulated_cost_domain_violation_panics_not_corrupts() {
    let c = CostFn::from_table(&[(0, 0.0), (1, 1.0)]);
    let result = std::panic::catch_unwind(|| c.eval(5));
    assert!(result.is_err());
}

#[test]
fn zero_capacity_instance_rejected_at_build() {
    assert!(Instance::new(1, vec![0], vec![0], vec![affine()]).is_err());
}
