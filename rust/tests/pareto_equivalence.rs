//! Deadline-constrained scheduling differential suite: certify
//! `BiFleet::solve_constrained` and `BiFleet::pareto_front` against the
//! testkit's exhaustive constrained oracle across the Table-2 scenario
//! grid (cost families × limit patterns) × time-model shapes.
//!
//! * **Zero divergence**: for every generated case and every candidate
//!   makespan cap τ (plus adversarial off-grid caps), the ε-constrained
//!   class-level solve agrees with flat per-device capping + exhaustive
//!   enumeration — on feasibility *and* on optimal energy — for ≥ 200
//!   `(case, τ)` comparisons.
//! * **Front shape**: fronts are strictly sorted, mutually non-dominated,
//!   every point's schedule is feasible on the flat instance, and the
//!   loosest point matches the τ = ∞ solve's energy.
//! * **Solver sweep**: every registered solver either rejects the capped
//!   instance with an error or returns a feasible schedule meeting the
//!   deadline and never beating the oracle's optimum.

use fedzero::sched::instance::Instance;
use fedzero::sched::pareto::{BiFleet, TimeModel};
use fedzero::sched::solver::SolverRegistry;
use fedzero::sched::validate;
use fedzero::testkit::instances::{
    constrained_bruteforce, sample_time_models, Case, DupShape, Family,
    LimitPattern, TimeShape, ALL_FAMILIES, ALL_LIMIT_PATTERNS, ALL_TIME_SHAPES,
};

/// Every solver the registry constructs. Each name must appear in this
/// classifier literally (the fedlint R4 audit keys on it), so a newly
/// registered solver cannot silently skip the constrained sweep below.
const SOLVERS: [&str; 12] = [
    "auto",
    "mc2mkp",
    "marin",
    "marco",
    "mardecun",
    "mardec",
    "bruteforce",
    "uniform",
    "random",
    "proportional",
    "greedy",
    "olar",
];

/// Build one reproducible bi-objective case: a Table-2 instance plus
/// class-consistent per-device time models of the given shape.
fn bi_case(
    seed: u64,
    family: Family,
    limits: LimitPattern,
    shape: TimeShape,
    t: usize,
) -> (Instance, Vec<TimeModel>, BiFleet) {
    let case = Case {
        seed,
        family,
        limits,
        dup: DupShape::Random,
        distinct: 3,
        max_dup: 2,
        t,
    };
    let inst = case.build();
    let times = sample_time_models(&inst, shape, seed ^ 0x71AE_D11E);
    let bi = BiFleet::from_flat(&inst, &times)
        .expect("sampled time models are class-consistent");
    (inst, times, bi)
}

/// τ grid for one case: every candidate makespan, midpoints between
/// consecutive candidates (same cap set as the lower neighbour — the
/// class-level and flat paths must agree there too), and a guaranteed
/// infeasible cap for error parity.
fn tau_grid(bi: &BiFleet) -> Vec<f64> {
    let candidates = bi.candidate_makespans();
    let mut taus = vec![-1.0];
    for w in candidates.windows(2) {
        taus.push(0.5 * (w[0] + w[1]));
    }
    taus.extend_from_slice(&candidates);
    taus
}

#[test]
fn constrained_solve_has_zero_divergence_from_the_flat_oracle() {
    let registry = SolverRegistry::with_defaults(11);
    let mut comparisons = 0usize;
    for (fi, &family) in ALL_FAMILIES.iter().enumerate() {
        for (li, &limits) in ALL_LIMIT_PATTERNS.iter().enumerate() {
            for (si, &shape) in ALL_TIME_SHAPES.iter().enumerate() {
                for rep in 0..2u64 {
                    let seed = 0xD3AD_11E5
                        ^ ((fi as u64) << 8)
                        ^ ((li as u64) << 16)
                        ^ ((si as u64) << 24)
                        ^ rep;
                    let t = 6 + (li % 3) + (rep as usize) * 3;
                    let (inst, times, bi) = bi_case(seed, family, limits, shape, t);
                    for tau in tau_grid(&bi) {
                        let got = bi
                            .solve_constrained(&registry, "mc2mkp", tau)
                            .unwrap_or_else(|e| {
                                panic!("seed {seed:#x} τ={tau}: solve errored: {e}")
                            });
                        let want = constrained_bruteforce(&inst, &times, tau);
                        comparisons += 1;
                        match (got, want) {
                            (None, None) => {}
                            (Some(p), Some((oracle_sched, oracle_energy))) => {
                                validate::check(&inst, &p.schedule).unwrap_or_else(
                                    |e| panic!("seed {seed:#x} τ={tau}: {e}"),
                                );
                                assert!(
                                    bi.makespan(&p.schedule) <= tau + 1e-9,
                                    "seed {seed:#x}: point busts its own cap τ={tau}"
                                );
                                assert!(
                                    bi.makespan(&oracle_sched) <= tau + 1e-9,
                                    "seed {seed:#x}: oracle busts the cap τ={tau}"
                                );
                                assert!(
                                    (p.energy - oracle_energy).abs() < 1e-9,
                                    "seed {seed:#x} τ={tau}: class-level optimum \
                                     {} != flat oracle {oracle_energy}",
                                    p.energy
                                );
                            }
                            (g, w) => panic!(
                                "seed {seed:#x} τ={tau}: feasibility parity broke \
                                 (solver feasible: {}, oracle feasible: {})",
                                g.is_some(),
                                w.is_some()
                            ),
                        }
                    }
                }
            }
        }
    }
    assert!(
        comparisons >= 200,
        "scenario grid shrank below the certification floor: {comparisons} < 200"
    );
}

#[test]
fn fronts_are_sorted_nondominated_and_anchor_the_unconstrained_optimum() {
    let registry = SolverRegistry::with_defaults(11);
    for (fi, &family) in ALL_FAMILIES.iter().enumerate() {
        for (si, &shape) in ALL_TIME_SHAPES.iter().enumerate() {
            let seed = 0xF407 ^ ((fi as u64) << 4) ^ ((si as u64) << 12);
            let (inst, _times, bi) =
                bi_case(seed, family, LimitPattern::Both, shape, 9);
            let front = bi.pareto_front(&registry, "mc2mkp").unwrap();
            assert!(!front.is_empty(), "seed {seed:#x}: empty front");
            for w in front.windows(2) {
                assert!(
                    w[0].makespan < w[1].makespan && w[0].energy > w[1].energy,
                    "seed {seed:#x}: front not strictly sorted/improving"
                );
            }
            for p in &front {
                validate::check(&inst, &p.schedule)
                    .unwrap_or_else(|e| panic!("seed {seed:#x}: {e}"));
                assert!(
                    (bi.makespan(&p.schedule) - p.makespan).abs() < 1e-12,
                    "seed {seed:#x}: recorded makespan drifts from the schedule"
                );
            }
            // The loosest point carries the unconstrained energy optimum
            // (duplicate-class ties can pick a different optimal schedule
            // at a tighter τ, so only the value is pinned here; the
            // bit-for-bit anchor lives in the pareto unit tests).
            let inf = bi
                .solve_constrained(&registry, "mc2mkp", f64::INFINITY)
                .unwrap()
                .expect("τ = ∞ is always feasible for a valid instance");
            let last = front.last().unwrap();
            assert!(
                (last.energy - inf.energy).abs() < 1e-9,
                "seed {seed:#x}: loosest point {} misses the unconstrained \
                 optimum {}",
                last.energy,
                inf.energy
            );
        }
    }
}

#[test]
fn every_registered_solver_respects_the_cap_and_never_beats_the_oracle() {
    let registry = SolverRegistry::with_defaults(11);
    let mut feasible_runs = 0usize;
    for (fi, &family) in ALL_FAMILIES.iter().enumerate() {
        for (si, &shape) in ALL_TIME_SHAPES.iter().enumerate() {
            let seed = 0x5013 ^ ((fi as u64) << 4) ^ ((si as u64) << 12);
            let (inst, times, bi) =
                bi_case(seed, family, LimitPattern::UpperOnly, shape, 8);
            let candidates = bi.candidate_makespans();
            let tau = candidates[candidates.len() / 2];
            let Some((_, oracle_energy)) = constrained_bruteforce(&inst, &times, tau)
            else {
                continue; // median cap infeasible for this case — skip
            };
            for name in SOLVERS {
                // Specialized solvers may reject instances outside their
                // Table-2 scenario; an error is acceptable, silence is not.
                let point = match bi.solve_constrained(&registry, name, tau) {
                    Err(_) => continue,
                    Ok(None) => panic!(
                        "seed {seed:#x} {name}: reported infeasible where the \
                         oracle found a schedule (τ={tau})"
                    ),
                    Ok(Some(p)) => p,
                };
                validate::check(&inst, &point.schedule)
                    .unwrap_or_else(|e| panic!("seed {seed:#x} {name}: {e}"));
                assert!(
                    point.makespan <= tau + 1e-9,
                    "seed {seed:#x} {name}: schedule busts the deadline"
                );
                assert!(
                    point.energy >= oracle_energy - 1e-9,
                    "seed {seed:#x} {name}: beat the exhaustive optimum \
                     ({} < {oracle_energy})",
                    point.energy
                );
                feasible_runs += 1;
            }
        }
    }
    assert!(
        feasible_runs >= SOLVERS.len(),
        "solver sweep collapsed: only {feasible_runs} feasible runs"
    );
}
