//! Property test for the fleet-scale redesign: **class-grouped solves are
//! equivalent to flat per-device solves** — same total cost, feasible
//! class assignment, feasible per-device expansion — across randomized
//! instances with *forced device duplication* (so `k < n` and the
//! class-aware code paths genuinely differ from the flat ones), for every
//! registered solver.
//!
//! Instances come from the shared testkit generator
//! (`fedzero::testkit::instances`); the sibling suite
//! `tests/shard_equivalence.rs` extends the same contract to the sharded
//! build pipeline with strict bit-level checks.
//!
//! Regime-specialized solvers are compared on instances inside their
//! Table 2 scenario (outside it both paths are merely "feasible", with no
//! cost contract to compare); arbitrary-regime solvers and all baselines
//! are compared everywhere.

use fedzero::sched::costs::CostFn;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::{validate, Solver, SolverRegistry};
use fedzero::testkit::instances::{Case, DupShape, Family, LimitPattern};
use fedzero::util::rng::Rng;

/// Generate a duplication-heavy instance for one sweep seed.
fn duplicated_instance(seed: u64, family: Family, limits: LimitPattern) -> Instance {
    Case {
        seed,
        family,
        limits,
        dup: DupShape::Random,
        distinct: 3,
        max_dup: 4,
        t: 6 + (seed as usize % 19),
    }
    .build()
}

/// Assert flat-path and class-path solves agree for every named solver.
fn assert_equivalent(inst: &Instance, names: &[&str], seed: u64) {
    let fleet = FleetInstance::from_flat(inst).unwrap();
    let registry = SolverRegistry::with_defaults(seed);
    for &name in names {
        let solver = registry.resolve(name).unwrap();
        // Same RNG stream on both sides: the `random` baseline must
        // reproduce bit-for-bit through the fleet adapter.
        let flat = solver
            .solve_flat_with_rng(inst, &mut Rng::new(seed ^ 0x5EED))
            .unwrap_or_else(|e| panic!("{name} flat failed: {e}"));
        validate::check(inst, &flat)
            .unwrap_or_else(|e| panic!("{name} flat infeasible: {e}"));

        let asg = solver
            .solve_with_rng(&fleet, &mut Rng::new(seed ^ 0x5EED))
            .unwrap_or_else(|e| panic!("{name} fleet failed: {e}"));
        asg.check(&fleet)
            .unwrap_or_else(|e| panic!("{name} class-infeasible: {e}"));
        let expanded = asg.expand(&fleet);
        validate::check(inst, &expanded)
            .unwrap_or_else(|e| panic!("{name} expansion infeasible: {e}"));

        let c_flat = validate::total_cost(inst, &flat);
        let c_fleet = validate::total_cost(inst, &expanded);
        let c_asg = asg.total_cost(&fleet);
        let tol = 1e-9 * c_flat.abs().max(1.0);
        assert!(
            (c_flat - c_fleet).abs() <= tol,
            "{name}: class-grouped {c_fleet} != flat {c_flat} on {inst:?}"
        );
        assert!(
            (c_asg - c_fleet).abs() <= tol,
            "{name}: Assignment::total_cost {c_asg} != expanded {c_fleet}"
        );
    }
}

/// Solvers with no regime requirement: the arbitrary-capable optima and
/// every baseline (flat-delegating adapters included).
const REGIME_FREE: [&str; 8] = [
    "mc2mkp", "auto", "uniform", "random", "proportional", "greedy", "olar",
    "dp",
];

#[test]
fn convex_instances_marin() {
    for seed in 0..12u64 {
        let inst = duplicated_instance(seed, Family::Convex, LimitPattern::Both);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["marin"], seed);
    }
}

#[test]
fn affine_instances_marin_marco() {
    for seed in 20..32u64 {
        let inst = duplicated_instance(seed, Family::Affine, LimitPattern::Both);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["marin", "marco"], seed);
    }
}

#[test]
fn concave_unlimited_instances_mardecun_mardec() {
    for seed in 40..52u64 {
        // UnlimitedWithLower: U = T with random nonzero lowers — still
        // effectively unlimited after the §5.2 transform, so MarDecUn's
        // remove/restore arithmetic is exercised with L > 0.
        let inst = duplicated_instance(
            seed,
            Family::Concave,
            LimitPattern::UnlimitedWithLower,
        );
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["mardecun", "mardec"], seed);
    }
}

#[test]
fn concave_limited_instances_mardec() {
    for seed in 60..72u64 {
        let inst = duplicated_instance(seed, Family::Concave, LimitPattern::Both);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["mardec"], seed);
    }
}

#[test]
fn arbitrary_instances_with_bruteforce_oracle() {
    for seed in 80..88u64 {
        // Tiny sizes: the oracle is exponential.
        let inst = Case {
            seed,
            family: Family::Tabulated,
            limits: LimitPattern::Both,
            dup: DupShape::Random,
            distinct: 2,
            max_dup: 2,
            t: 4 + (seed as usize % 5),
        }
        .build();
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["bruteforce"], seed);
    }
}

#[test]
fn duplication_actually_produces_multiplicity_classes() {
    // Sanity on the generator itself: at least one instance in the sweep
    // must dedup below its device count, or the whole suite tests nothing.
    let mut seen_dedup = false;
    for seed in 0..12u64 {
        let inst = duplicated_instance(seed, Family::Affine, LimitPattern::Both);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        assert!(fleet.n_classes() <= fleet.n_devices());
        if fleet.n_classes() < fleet.n_devices() {
            seen_dedup = true;
        }
    }
    assert!(seen_dedup, "generator never produced a duplicated device");
}

#[test]
fn mardecun_error_parity_on_limited_instances() {
    // Flat MarDecUn rejects effectively-limited instances; the class path
    // must reject them identically instead of silently "solving".
    let inst = Instance::new(
        9,
        vec![0, 0],
        vec![4, 9],
        vec![
            CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
            CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.5 },
        ],
    )
    .unwrap();
    let registry = SolverRegistry::with_defaults(1);
    let solver = registry.resolve("mardecun").unwrap();
    assert!(solver.solve_flat(&inst).is_err());
    let fleet = FleetInstance::from_flat(&inst).unwrap();
    assert!(solver.solve(&fleet).is_err());
}
