//! Property test for the fleet-scale redesign: **class-grouped solves are
//! equivalent to flat per-device solves** — same total cost, feasible
//! class assignment, feasible per-device expansion — across randomized
//! instances with *forced device duplication* (so `k < n` and the
//! class-aware code paths genuinely differ from the flat ones), for every
//! registered solver.
//!
//! Regime-specialized solvers are compared on instances inside their
//! Table 2 scenario (outside it both paths are merely "feasible", with no
//! cost contract to compare); arbitrary-regime solvers and all baselines
//! are compared everywhere.

use fedzero::sched::costs::CostFn;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::{validate, Solver, SolverRegistry};
use fedzero::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
enum Family {
    Convex,
    Affine,
    Concave,
    Tabulated,
}

fn sample_cost(family: Family, t: usize, rng: &mut Rng) -> CostFn {
    match family {
        Family::Convex => CostFn::Quadratic {
            fixed: rng.range_f64(0.0, 2.0),
            a: rng.range_f64(0.01, 1.0),
            b: rng.range_f64(0.0, 3.0),
        },
        Family::Affine => CostFn::Affine {
            fixed: rng.range_f64(0.0, 2.0),
            per_task: rng.range_f64(0.1, 4.0),
        },
        Family::Concave => {
            if rng.bool(0.5) {
                CostFn::PowerLaw {
                    fixed: rng.range_f64(0.0, 1.0),
                    scale: rng.range_f64(0.3, 4.0),
                    exponent: rng.range_f64(0.2, 0.95),
                }
            } else {
                CostFn::Logarithmic {
                    fixed: rng.range_f64(0.0, 1.0),
                    scale: rng.range_f64(0.3, 4.0),
                }
            }
        }
        Family::Tabulated => {
            let mut values = vec![0.0];
            let mut acc = 0.0;
            for _ in 1..=t {
                acc += rng.range_f64(0.0, 3.0);
                values.push((acc + rng.normal() * 0.5).max(0.0));
            }
            CostFn::Tabulated { first: 0, values }
        }
    }
}

/// Build an instance of `distinct` device specs, each replicated up to
/// `max_dup` times (identical `(C, L, U)` triples ⇒ classes with
/// multiplicity), repaired to feasibility.
fn duplicated_instance(
    seed: u64,
    family: Family,
    distinct: usize,
    max_dup: usize,
    max_t: usize,
    unlimited: bool,
) -> Instance {
    let mut rng = Rng::new(seed);
    let t = 6 + rng.index(max_t.saturating_sub(5).max(1));
    let mut costs = Vec::new();
    let mut lower = Vec::new();
    let mut upper = Vec::new();
    for _ in 0..1 + rng.index(distinct) {
        let cost = sample_cost(family, t, &mut rng);
        let u = if unlimited { t } else { 1 + rng.index(t) };
        let l = rng.index((u / 2).max(1));
        for _ in 0..1 + rng.index(max_dup) {
            costs.push(cost.clone());
            lower.push(l);
            upper.push(u);
        }
    }
    // Repair: shrink lowers until ΣL <= T, grow uppers until ΣU >= T.
    // (Uniform growth keeps duplicated specs identical, preserving dedup.)
    let n = costs.len();
    let mut i = 0;
    while lower.iter().sum::<usize>() > t {
        if lower[i % n] > 0 {
            lower[i % n] -= 1;
        }
        i += 1;
    }
    while upper.iter().map(|&u| u.min(t)).sum::<usize>() < t {
        for u in upper.iter_mut() {
            *u += 1;
        }
    }
    Instance::new(t, lower, upper, costs).expect("generated instance valid")
}

/// Assert flat-path and class-path solves agree for every named solver.
fn assert_equivalent(inst: &Instance, names: &[&str], seed: u64) {
    let fleet = FleetInstance::from_flat(inst).unwrap();
    let registry = SolverRegistry::with_defaults(seed);
    for &name in names {
        let solver = registry.resolve(name).unwrap();
        // Same RNG stream on both sides: the `random` baseline must
        // reproduce bit-for-bit through the fleet adapter.
        let flat = solver
            .solve_flat_with_rng(inst, &mut Rng::new(seed ^ 0x5EED))
            .unwrap_or_else(|e| panic!("{name} flat failed: {e}"));
        validate::check(inst, &flat)
            .unwrap_or_else(|e| panic!("{name} flat infeasible: {e}"));

        let asg = solver
            .solve_with_rng(&fleet, &mut Rng::new(seed ^ 0x5EED))
            .unwrap_or_else(|e| panic!("{name} fleet failed: {e}"));
        asg.check(&fleet)
            .unwrap_or_else(|e| panic!("{name} class-infeasible: {e}"));
        let expanded = asg.expand(&fleet);
        validate::check(inst, &expanded)
            .unwrap_or_else(|e| panic!("{name} expansion infeasible: {e}"));

        let c_flat = validate::total_cost(inst, &flat);
        let c_fleet = validate::total_cost(inst, &expanded);
        let c_asg = asg.total_cost(&fleet);
        let tol = 1e-9 * c_flat.abs().max(1.0);
        assert!(
            (c_flat - c_fleet).abs() <= tol,
            "{name}: class-grouped {c_fleet} != flat {c_flat} on {inst:?}"
        );
        assert!(
            (c_asg - c_fleet).abs() <= tol,
            "{name}: Assignment::total_cost {c_asg} != expanded {c_fleet}"
        );
    }
}

/// Solvers with no regime requirement: the arbitrary-capable optima and
/// every baseline (flat-delegating adapters included).
const REGIME_FREE: [&str; 8] = [
    "mc2mkp", "auto", "uniform", "random", "proportional", "greedy", "olar",
    "dp",
];

#[test]
fn convex_instances_marin() {
    for seed in 0..12u64 {
        let inst = duplicated_instance(seed, Family::Convex, 3, 4, 30, false);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["marin"], seed);
    }
}

#[test]
fn affine_instances_marin_marco() {
    for seed in 20..32u64 {
        let inst = duplicated_instance(seed, Family::Affine, 3, 4, 30, false);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["marin", "marco"], seed);
    }
}

#[test]
fn concave_unlimited_instances_mardecun_mardec() {
    for seed in 40..52u64 {
        let inst = duplicated_instance(seed, Family::Concave, 3, 4, 24, true);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["mardecun", "mardec"], seed);
    }
}

#[test]
fn concave_limited_instances_mardec() {
    for seed in 60..72u64 {
        let inst = duplicated_instance(seed, Family::Concave, 3, 4, 24, false);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["mardec"], seed);
    }
}

#[test]
fn arbitrary_instances_with_bruteforce_oracle() {
    for seed in 80..88u64 {
        // Tiny sizes: the oracle is exponential.
        let inst = duplicated_instance(seed, Family::Tabulated, 2, 2, 9, false);
        assert_equivalent(&inst, &REGIME_FREE, seed);
        assert_equivalent(&inst, &["bruteforce"], seed);
    }
}

#[test]
fn duplication_actually_produces_multiplicity_classes() {
    // Sanity on the generator itself: at least one instance in the sweep
    // must dedup below its device count, or the whole suite tests nothing.
    let mut seen_dedup = false;
    for seed in 0..12u64 {
        let inst = duplicated_instance(seed, Family::Affine, 3, 4, 30, false);
        let fleet = FleetInstance::from_flat(&inst).unwrap();
        assert!(fleet.n_classes() <= fleet.n_devices());
        if fleet.n_classes() < fleet.n_devices() {
            seen_dedup = true;
        }
    }
    assert!(seen_dedup, "generator never produced a duplicated device");
}

#[test]
fn mardecun_error_parity_on_limited_instances() {
    // Flat MarDecUn rejects effectively-limited instances; the class path
    // must reject them identically instead of silently "solving".
    let inst = Instance::new(
        9,
        vec![0, 0],
        vec![4, 9],
        vec![
            CostFn::PowerLaw { fixed: 0.0, scale: 1.0, exponent: 0.5 },
            CostFn::PowerLaw { fixed: 0.0, scale: 2.0, exponent: 0.5 },
        ],
    )
    .unwrap();
    let registry = SolverRegistry::with_defaults(1);
    let solver = registry.resolve("mardecun").unwrap();
    assert!(solver.solve_flat(&inst).is_err());
    let fleet = FleetInstance::from_flat(&inst).unwrap();
    assert!(solver.solve(&fleet).is_err());
}
