//! Integration tests pinned to the paper's own numbers (§3.1, Figs. 1–2)
//! and to cross-algorithm agreement on the worked example.

use fedzero::sched::instance::{Instance, Schedule};
use fedzero::sched::{baselines, bruteforce, mc2mkp, validate, SolverRegistry};
use fedzero::util::rng::Rng;

#[test]
fn fig1_optimal_schedule() {
    let inst = Instance::paper_example(5);
    let s = mc2mkp::solve(&inst).unwrap();
    assert_eq!(s.assignments(), &[2, 3, 0]);
    assert!((validate::checked_cost(&inst, &s).unwrap() - 7.5).abs() < 1e-12);
}

#[test]
fn fig2_optimal_schedule() {
    let inst = Instance::paper_example(8);
    let s = mc2mkp::solve(&inst).unwrap();
    assert_eq!(s.assignments(), &[1, 2, 5]);
    assert!((validate::checked_cost(&inst, &s).unwrap() - 11.5).abs() < 1e-12);
}

#[test]
fn fig1_lower_limit_matters() {
    // Without L_1 = 1 the optimum would put everything on resource 3
    // (C3(5) = 7 vs 7.5) — the paper's §3.1 commentary. Resource 1's
    // tabulated cost must be extended to j = 0 for the relaxed domain.
    let mut inst = Instance::paper_example(5);
    inst.lower[0] = 0;
    inst.costs[0] = fedzero::sched::costs::CostFn::from_table(&[
        (0, 0.0), (1, 2.0), (2, 3.5), (3, 5.5), (4, 8.0), (5, 10.0), (6, 12.0),
    ]);
    let s = mc2mkp::solve(&inst).unwrap();
    assert_eq!(s.assignments(), &[0, 0, 5]);
    assert!((validate::total_cost(&inst, &s) - 7.0).abs() < 1e-12);
}

#[test]
fn fig2_hits_both_limits() {
    // X* = {1, 2, 5} reaches L_1 = 1 and U_3 = 5 (paper's observation).
    let inst = Instance::paper_example(8);
    let s = mc2mkp::solve(&inst).unwrap();
    assert_eq!(s.get(0), inst.lower[0]);
    assert_eq!(s.get(2), inst.upper[2]);
}

#[test]
fn brute_force_confirms_both_figures() {
    for (t, cost) in [(5usize, 7.5), (8, 11.5)] {
        let inst = Instance::paper_example(t);
        let s = bruteforce::solve(&inst).unwrap();
        assert!((validate::checked_cost(&inst, &s).unwrap() - cost).abs() < 1e-12);
    }
}

#[test]
fn every_t_from_1_to_17_solvable_and_oracle_optimal() {
    // ΣL = 1, ΣU = 17 on the example — all feasible T values.
    for t in 1..=17 {
        let inst = Instance::paper_example(t);
        let dp = mc2mkp::solve(&inst).unwrap();
        let bf = bruteforce::solve(&inst).unwrap();
        let cd = validate::checked_cost(&inst, &dp).unwrap();
        let cb = validate::checked_cost(&inst, &bf).unwrap();
        assert!((cd - cb).abs() < 1e-9, "T={t}: dp {cd} != brute {cb}");
    }
}

#[test]
fn all_baselines_feasible_on_example() {
    let inst = Instance::paper_example(8);
    let mut rng = Rng::new(1);
    let registry = SolverRegistry::with_defaults(1);
    for policy in ["uniform", "random", "proportional", "greedy", "olar"] {
        let s = registry.solve_seeded(policy, &inst, &mut rng).unwrap();
        validate::check(&inst, &s)
            .unwrap_or_else(|e| panic!("{policy} infeasible: {e}"));
        let c = validate::total_cost(&inst, &s);
        assert!(c >= 11.5 - 1e-9, "{policy} beat the optimum: {c}");
    }
}

#[test]
fn olar_on_example_minimizes_max_cost() {
    let inst = Instance::paper_example(8);
    let olar = baselines::olar(&inst).unwrap();
    let opt_total = mc2mkp::solve(&inst).unwrap();
    // OLAR's max per-resource cost is no worse than the total-optimal
    // schedule's max cost (it optimizes the other objective).
    assert!(
        validate::max_cost(&inst, &olar) <= validate::max_cost(&inst, &opt_total) + 1e-9
    );
}

#[test]
fn schedule_display_roundtrip() {
    let s = Schedule::new(vec![1, 2, 5]);
    assert_eq!(s.to_string(), "{1, 2, 5}");
    assert_eq!(s.total(), 8);
}
