//! Differential proof of the sharded scheduling pipeline:
//! **sharded ≡ class ≡ flat** for every registered solver.
//!
//! Structural half: for shard counts 1, `n` (all-singleton shards), a
//! prime that does not divide the fleet, and `n + 3` (trailing empty
//! shards), the merged fleet must be **bit-identical** to
//! `FleetInstance::from_flat` — same digest, same class order, same
//! member lists.
//!
//! Behavioral half: solving the sharded-built fleet must reproduce the
//! class solve exactly (assignment + cost bits) and agree with the flat
//! solve (bit-for-bit for flat-delegating solvers, cost-equal for
//! class-aware cores); a path that rejects an instance must be rejected
//! by every path. The shared oracle lives in
//! `fedzero::testkit::instances::check_shard_class_flat`.
//!
//! The fuzz loop sweeps Table 2 cost families × adversarial limit
//! patterns (tight lowers, pinned loads) × duplication shapes
//! (single-class, all-unique, random), and keeps generating until every
//! one of the 12 registered solvers has accumulated **≥ 200** seeded
//! zero-divergence cases — the PR's acceptance bar.

use std::collections::BTreeMap;

use fedzero::runtime::pool;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::{costs::CostFn, shard, SolverRegistry};
use fedzero::testkit::instances::{
    check_shard_class_flat, coprime_shards, Case, DupShape, Family, LimitPattern,
};

/// Every registered solver name — derived from the registry, not
/// hand-maintained, so a newly registered solver automatically joins the
/// fuzz (and must be classified by [`runs_on`], which panics on unknown
/// names).
fn all_solvers() -> Vec<&'static str> {
    SolverRegistry::with_defaults(0).names()
}

/// Which scenario cells a solver joins the path-equivalence fuzz on.
/// Regime-free solvers (the arbitrary-capable optima, the dispatcher,
/// every baseline) run everywhere; regime-specialized solvers only where
/// flat and class solves carry a cost contract (outside their regime the
/// two paths are merely feasible and may legitimately diverge); the
/// exhaustive oracle only on tiny instances.
fn runs_on(name: &str, family: Family, tiny: bool) -> bool {
    match name {
        "auto" | "mc2mkp" | "uniform" | "random" | "proportional" | "greedy"
        | "olar" => true,
        "bruteforce" => tiny,
        "marin" => matches!(family, Family::Convex | Family::Affine),
        "marco" => matches!(family, Family::Affine),
        "mardec" | "mardecun" => {
            matches!(family, Family::Concave | Family::Affine)
        }
        other => panic!(
            "solver '{other}' is registered but unclassified — add it to \
             runs_on so the shard fuzz covers it"
        ),
    }
}

#[test]
fn fuzz_shard_class_flat_equivalence_reaches_200_cases_per_solver() {
    const TARGET: usize = 200;
    let solvers = all_solvers();
    let mut counts: BTreeMap<&str, usize> =
        solvers.iter().map(|&s| (s, 0usize)).collect();
    // Scenario cycle engineered so every solver's applicable combos recur
    // often (marco is the rarest at 4-in-10).
    let combos: [(Family, LimitPattern, DupShape); 10] = [
        (Family::Convex, LimitPattern::Both, DupShape::Random),
        (Family::Affine, LimitPattern::Unlimited, DupShape::SingleClass),
        (Family::Concave, LimitPattern::UnlimitedWithLower, DupShape::Random),
        (Family::Tabulated, LimitPattern::Both, DupShape::Random),
        (Family::Affine, LimitPattern::UpperOnly, DupShape::Random),
        (Family::Concave, LimitPattern::Both, DupShape::AllUnique),
        (Family::Convex, LimitPattern::TightLower, DupShape::Random),
        (Family::Affine, LimitPattern::Pinned, DupShape::SingleClass),
        (
            Family::Concave,
            LimitPattern::UnlimitedWithLower,
            DupShape::SingleClass,
        ),
        (Family::Affine, LimitPattern::Both, DupShape::Random),
    ];
    let mut case_idx: u64 = 0;
    while counts.values().any(|&c| c < TARGET) {
        assert!(
            case_idx < 20_000,
            "fuzz failed to reach {TARGET} cases/solver: {counts:?}"
        );
        let (family, limits, dup) = combos[(case_idx as usize) % combos.len()];
        let case = Case {
            seed: 0x51AD ^ case_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            family,
            limits,
            dup,
            distinct: 3,
            max_dup: 2,
            t: 4 + (case_idx as usize % 5),
        };
        let inst = case.build();
        let n = inst.n();
        let tiny = n <= 4 && inst.tasks <= 8;
        let shard_counts = [1usize, n, coprime_shards(n), n + 3];
        for &name in &solvers {
            if !runs_on(name, family, tiny) {
                continue;
            }
            check_shard_class_flat(&inst, name, &shard_counts, case.seed)
                .unwrap_or_else(|e| panic!("case {case:?}: {e}"));
            *counts.get_mut(name).unwrap() += 1;
        }
        case_idx += 1;
    }
    for (name, c) in counts {
        assert!(c >= TARGET, "{name}: only {c} zero-divergence cases");
    }
    println!("fuzz complete after {case_idx} generated instances");
}

fn affine(per_task: f64) -> CostFn {
    CostFn::Affine { fixed: 0.0, per_task }
}

#[test]
fn degenerate_shards_empty_single_class_all_unique() {
    // Single class: every shard holds a slice of the same signature.
    let n = 10;
    let single = Instance::new(
        8,
        vec![0; n],
        vec![8; n],
        vec![affine(1.5); n],
    )
    .unwrap();
    // All-unique: k = n, nothing fuses.
    let unique = Instance::new(
        8,
        vec![0; n],
        vec![8; n],
        (0..n).map(|i| affine(1.0 + i as f64)).collect(),
    )
    .unwrap();
    for inst in [&single, &unique] {
        let flat = FleetInstance::from_flat(inst).unwrap();
        // shards > n ⇒ trailing empty shards; shards = n ⇒ singletons;
        // prime 7 ∤ 10 ⇒ uneven remainder.
        for shards in [1usize, 7, n, n + 5] {
            let (built, _) = shard::build_sharded(inst, shards).unwrap();
            assert_eq!(built.digest(), flat.digest(), "shards={shards}");
        }
        for name in all_solvers() {
            // Affine fleets: every solver is in-regime; the oracle is fine
            // at n = 10, T = 8 thanks to its feasibility pruning.
            check_shard_class_flat(inst, name, &[1, 7, n, n + 5], 0xD0_0D)
                .unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn parallel_driver_matches_sequential_sharding_at_scale() {
    // 10⁴ devices in 16 interleaved classes: the scoped-thread driver and
    // the sequential sharded build and the direct build all agree to the
    // bit, for worker counts above, at, and below the shard count.
    let n = 10_000;
    let costs: Vec<CostFn> = (0..n).map(|i| affine(1.0 + (i % 16) as f64)).collect();
    let inst = Instance::new(2 * n, vec![0; n], vec![4; n], costs).unwrap();
    let flat = FleetInstance::from_flat(&inst).unwrap();
    assert_eq!(flat.n_classes(), 16);
    for (shards, workers) in [(8usize, 0usize), (8, 3), (13, 2), (64, 8)] {
        let (seq, _) = shard::build_sharded(&inst, shards).unwrap();
        let (par, stats) = pool::build_fleet_sharded(&inst, shards, workers).unwrap();
        assert_eq!(stats.shards, shards);
        assert_eq!(seq.digest(), flat.digest());
        assert_eq!(par.digest(), flat.digest());
    }
}

#[test]
fn pinned_and_tight_lower_instances_survive_every_path() {
    // The adversarial limit patterns: pinned loads (T' = 0 after the §5.2
    // transform) and tight lower limits (schedule fully forced).
    for (seed, limits) in [
        (1u64, LimitPattern::Pinned),
        (2, LimitPattern::Pinned),
        (3, LimitPattern::TightLower),
        (4, LimitPattern::TightLower),
    ] {
        for family in [Family::Affine, Family::Concave, Family::Convex] {
            let case = Case {
                seed: seed ^ 0xF1EE7,
                family,
                limits,
                dup: DupShape::Random,
                distinct: 3,
                max_dup: 2,
                t: 7,
            };
            let inst = case.build();
            let n = inst.n();
            for name in ["auto", "mc2mkp", "uniform", "random", "proportional",
                "greedy", "olar"]
            {
                check_shard_class_flat(
                    &inst,
                    name,
                    &[1, n, coprime_shards(n)],
                    case.seed,
                )
                .unwrap_or_else(|e| panic!("{limits:?}/{family:?}: {e}"));
            }
        }
    }
}
