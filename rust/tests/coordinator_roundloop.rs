//! Integration tests for the coordinator round loop: multi-round runs over
//! the simulation backend (no PJRT artifacts needed), feasibility and
//! energy invariants, the §3.1 worked example through the full state
//! machine, and warm-start-vs-cold-solve equivalence.

use fedzero::coordinator::{
    Coordinator, CoordinatorConfig, ManagedDevice, Phase, SimBackend,
};
use fedzero::fl::dynamics::{Availability, CostDrift, Dropout, DynamicsConfig};
use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::mc2mkp::{self, WarmMc2mkp};
use fedzero::sched::validate;
use fedzero::util::rng::Rng;

/// A deterministic synthetic fleet with convex (increasing-marginal)
/// energy profiles — the regime where scheduling matters most per joule.
fn convex_fleet(n: usize, seed: u64) -> Vec<ManagedDevice> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                CostFn::Quadratic {
                    fixed: 0.0,
                    a: rng.range_f64(0.05, 0.5),
                    b: rng.range_f64(0.5, 3.0),
                },
                0,
                8 + rng.index(24),
            )
        })
        .collect()
}

fn cfg(algo: &str, rounds: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        rounds,
        tasks_per_round: 40,
        algo: algo.into(),
        max_share: 1.0,
        seed: 1234,
        ..CoordinatorConfig::default()
    }
}

#[test]
fn paper_example_through_the_full_state_machine() {
    // The §3.1 worked example driven by the coordinator: round 1 must land
    // exactly on X* = {2, 3, 0} with ΣC = 7.5 at T = 5.
    let inst = Instance::paper_example(5);
    let devices: Vec<ManagedDevice> = (0..inst.n())
        .map(|i| {
            ManagedDevice::abstract_resource(
                i,
                inst.costs[i].clone(),
                inst.lower[i],
                inst.upper[i],
            )
        })
        .collect();
    let cfg = CoordinatorConfig {
        rounds: 2,
        tasks_per_round: 5,
        algo: "mc2mkp".into(),
        max_share: 1.0,
        ..CoordinatorConfig::default()
    };
    let mut coord = Coordinator::new(cfg, devices, SimBackend::new()).unwrap();
    assert_eq!(coord.phase(), Phase::Configuring);
    let r1 = coord.round().unwrap();
    assert_eq!(r1.tasks, 5);
    assert!((r1.energy_j - 7.5).abs() < 1e-9, "ΣC = {}", r1.energy_j);
    // Round 2 re-solves warm (static costs → every DP row reused) and must
    // land on the identical optimum.
    let r2 = coord.round().unwrap();
    assert_eq!(r2.energy_j, r1.energy_j, "warm re-solve differs from round 1");
    assert_eq!(coord.metrics().counter("dp_rows_reused"), 3);
}

#[test]
fn multi_round_schedules_stay_feasible_under_dynamics() {
    // A seeded fleet with churn + drift + dropout: every round the
    // coordinator-internal validation must hold (round() errors if a
    // schedule is infeasible), rounds must all be logged, and energy must
    // stay non-negative and finite.
    let n = 12;
    let mut coord =
        Coordinator::new(cfg("auto", 25), convex_fleet(n, 9), SimBackend::new())
            .unwrap();
    coord.set_dynamics(DynamicsConfig {
        availability: Some(Availability::new(n, 0.4, 0.2)),
        drift: Some(CostDrift::new(n, 0.1)),
        dropout: Some(Dropout { p_fail: 0.1 }),
    });
    let log = coord.run().unwrap();
    assert_eq!(log.rows().len(), 25);
    for row in log.rows() {
        assert!(row.energy_j.is_finite() && row.energy_j >= 0.0);
        assert!(row.participants <= n);
    }
    // Ledger and per-round log agree.
    let from_rows: f64 = coord.log().rows().iter().map(|r| r.energy_j).sum();
    assert!((from_rows - coord.ledger().total()).abs() < 1e-6);
}

#[test]
fn optimal_total_energy_is_no_worse_than_uniform_every_round() {
    // Same fleet, same seed, convex costs: the auto-dispatched optimal
    // schedule must use at most the uniform baseline's energy in EVERY
    // round, hence also in total.
    let run = |algo: &str| {
        let mut coord =
            Coordinator::new(cfg(algo, 10), convex_fleet(16, 77), SimBackend::new())
                .unwrap();
        coord.run().unwrap();
        coord
            .log()
            .rows()
            .iter()
            .map(|r| r.energy_j)
            .collect::<Vec<f64>>()
    };
    let opt = run("auto");
    let uni = run("uniform");
    assert_eq!(opt.len(), uni.len());
    for (r, (o, u)) in opt.iter().zip(&uni).enumerate() {
        assert!(o <= &(u + 1e-9), "round {r}: optimal {o} J > uniform {u} J");
    }
    assert!(opt.iter().sum::<f64>() <= uni.iter().sum::<f64>() + 1e-9);
}

#[test]
fn deterministic_trajectory_for_a_seed() {
    let run = || {
        let n = 10;
        let mut coord =
            Coordinator::new(cfg("auto", 12), convex_fleet(n, 5), SimBackend::new())
                .unwrap();
        coord.set_dynamics(DynamicsConfig {
            availability: Some(Availability::new(n, 0.5, 0.3)),
            drift: Some(CostDrift::new(n, 0.2)),
            dropout: Some(Dropout { p_fail: 0.2 }),
        });
        coord.run().unwrap();
        coord
            .log()
            .rows()
            .iter()
            .map(|r| (r.energy_j, r.participants, r.tasks))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

/// Property test: warm-started (MC)²MKP re-solves are bit-for-bit equal to
/// cold solves across randomized drift sequences that mutate a random
/// suffix of the cost tables each round (including the empty suffix — a
/// full-reuse re-solve — and the full fleet — an effectively cold one).
#[test]
fn warm_resolve_equals_cold_solve_property() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..40 {
        let n = 2 + rng.index(5);
        let t = 5 + rng.index(30);
        let base: Vec<CostFn> = (0..n)
            .map(|_| {
                // Tabulated (arbitrary-regime) costs so the DP is the only
                // optimal solver and every round really exercises it.
                let mut acc = 0.0;
                let values: Vec<f64> = (0..=t)
                    .map(|j| {
                        if j > 0 {
                            acc += rng.range_f64(0.1, 2.0);
                        }
                        acc + rng.f64()
                    })
                    .collect();
                CostFn::Tabulated { first: 0, values }
            })
            .collect();
        let uppers: Vec<usize> = (0..n).map(|_| 1 + rng.index(t)).collect();
        let mut uppers = uppers;
        while uppers.iter().map(|&u| u.min(t)).sum::<usize>() < t {
            for u in uppers.iter_mut() {
                *u += 1;
            }
        }

        let mut warm = WarmMc2mkp::new();
        let mut scales = vec![1.0f64; n];
        for round in 0..6 {
            // Drift a random suffix (or nothing) between rounds.
            if round > 0 {
                let from = rng.index(n + 1);
                for s in scales.iter_mut().skip(from) {
                    *s *= rng.range_f64(0.8, 1.25);
                }
            }
            let costs: Vec<CostFn> = base
                .iter()
                .zip(&scales)
                .map(|(c, &w)| CostFn::Scaled { weight: w, inner: Box::new(c.clone()) })
                .collect();
            let inst = Instance::new(t, vec![0; n], uppers.clone(), costs).unwrap();
            let (warm_sched, _info) = warm.solve(&inst).unwrap();
            let cold_sched = mc2mkp::solve(&inst).unwrap();
            assert_eq!(
                warm_sched, cold_sched,
                "case {case} round {round}: warm != cold"
            );
            // Costs agree exactly (==, not within tolerance): identical
            // arithmetic must produce identical bits.
            assert_eq!(
                validate::checked_cost(&inst, &warm_sched).unwrap(),
                validate::checked_cost(&inst, &cold_sched).unwrap(),
            );
        }
    }
}

#[test]
fn empty_pool_rounds_are_logged_without_energy() {
    let n = 6;
    let mut coord =
        Coordinator::new(cfg("auto", 8), convex_fleet(n, 3), SimBackend::new())
            .unwrap();
    // Everyone leaves and never rejoins: after the first round the pool is
    // empty, so later rounds must be empty rounds.
    coord.set_dynamics(DynamicsConfig {
        availability: Some(Availability::new(n, 0.0, 1.0)),
        drift: None,
        dropout: None,
    });
    coord.run().unwrap();
    assert_eq!(coord.log().rows().len(), 8);
    assert!(coord.metrics().counter("empty_rounds") >= 7);
    let tail_energy: f64 = coord
        .log()
        .rows()
        .iter()
        .skip(1)
        .map(|r| r.energy_j)
        .sum();
    assert_eq!(tail_energy, 0.0);
}
