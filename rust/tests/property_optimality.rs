//! Property-based certification of the paper's optimality theorems
//! (Theorems 1–5) against executable oracles, driven by the shared
//! testkit instance generator (`fedzero::testkit::instances` — Table 2
//! cost families × adversarial limit patterns × duplication shapes):
//!
//! * every specialized algorithm matches the (MC)²MKP DP on its scenario;
//! * the DP matches brute-force enumeration on small instances;
//! * **seeded differential testing vs the brute-force oracle**: every one
//!   of the 12 registered solvers accumulates ≥ 200 random small-instance
//!   cases — optimal solvers must hit the oracle's cost exactly (within
//!   float tolerance), baselines must stay feasible and never beat it;
//! * every produced schedule is feasible (eq. 1b–1c invariants);
//! * the §5.2 lower-limit transformation preserves optima.

use std::collections::BTreeMap;

use fedzero::sched::instance::Instance;
use fedzero::sched::{
    auto, bruteforce, limits, marco, mardec, mardecun, marin, mc2mkp,
    validate, Schedule, SolverRegistry,
};
use fedzero::testkit::instances::{Case, CaseGen, DupShape, Family, LimitPattern};
use fedzero::testkit::{close, ensure, forall, Config};
use fedzero::util::rng::Rng;

fn gen_for(family: Family, limits: LimitPattern, max_t: usize) -> CaseGen {
    CaseGen {
        family,
        limits,
        dup: DupShape::Random,
        max_distinct: 3,
        max_dup: 2,
        max_t,
    }
}

fn check_matches_dp(
    case: &Case,
    solver: fn(&Instance) -> fedzero::Result<Schedule>,
) -> Result<(), String> {
    let inst = case.build();
    let s = solver(&inst).map_err(|e| format!("solver failed: {e}"))?;
    validate::check(&inst, &s).map_err(|e| format!("infeasible: {e}"))?;
    let c = validate::total_cost(&inst, &s);
    let dp = mc2mkp::solve(&inst).map_err(|e| format!("dp failed: {e}"))?;
    let cd = validate::total_cost(&inst, &dp);
    close(c, cd, 1e-6 * cd.abs().max(1.0), "cost vs DP")
}

#[test]
fn dp_matches_bruteforce_on_small_arbitrary_instances() {
    let gen = gen_for(Family::Tabulated, LimitPattern::Both, 10);
    let cfg = Config { cases: 150, seed: 0x5EED_0001, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| {
        let inst = case.build();
        let dp = mc2mkp::solve(&inst).map_err(|e| e.to_string())?;
        let bf = bruteforce::solve(&inst).map_err(|e| e.to_string())?;
        validate::check(&inst, &dp).map_err(|e| e.to_string())?;
        close(
            validate::total_cost(&inst, &dp),
            validate::total_cost(&inst, &bf),
            1e-9,
            "dp vs brute force",
        )
    });
}

#[test]
fn marin_optimal_on_convex() {
    let gen = gen_for(Family::Convex, LimitPattern::Both, 50);
    let cfg = Config { cases: 120, seed: 0x5EED_0002, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| check_matches_dp(case, marin::solve));
}

#[test]
fn marco_optimal_on_affine() {
    let gen = gen_for(Family::Affine, LimitPattern::Both, 50);
    let cfg = Config { cases: 120, seed: 0x5EED_0003, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| check_matches_dp(case, marco::solve));
}

#[test]
fn mardecun_optimal_on_concave_unlimited() {
    // UnlimitedWithLower: U = T with random nonzero lowers — effectively
    // unlimited after the §5.2 transform, exercising MarDecUn's
    // remove/restore arithmetic, not just the L = 0 fast path.
    let gen = gen_for(Family::Concave, LimitPattern::UnlimitedWithLower, 40);
    let cfg = Config { cases: 120, seed: 0x5EED_0004, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| check_matches_dp(case, mardecun::solve));
}

#[test]
fn auto_optimal_across_families() {
    // `auto` must classify correctly and return an optimum for every
    // family at workload sizes well beyond the oracle-tiny differential
    // (classification thresholds only show up over wider domains).
    for (family, limits, seed) in [
        (Family::Convex, LimitPattern::Both, 0x5EED_0006u64),
        (Family::Affine, LimitPattern::Both, 0x5EED_0007),
        (Family::Concave, LimitPattern::UnlimitedWithLower, 0x5EED_0008),
        (Family::Concave, LimitPattern::Both, 0x5EED_000D),
        (Family::Tabulated, LimitPattern::Both, 0x5EED_0009),
    ] {
        let gen = gen_for(family, limits, 30);
        let cfg = Config { cases: 60, seed, ..Default::default() };
        forall(&cfg, &gen, |case: &Case| {
            check_matches_dp(case, auto::solve_auto)
        });
    }
}

#[test]
fn mardec_optimal_on_concave_limited() {
    let gen = gen_for(Family::Concave, LimitPattern::Both, 30);
    let cfg = Config { cases: 120, seed: 0x5EED_0005, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| check_matches_dp(case, mardec::solve));
}

#[test]
fn specialized_solvers_survive_adversarial_limit_patterns() {
    // Tight lower limits (ΣL = T) and pinned loads (L = U) force the
    // schedule; every optimal algorithm must return it, matching the DP.
    for (limits, seed) in [
        (LimitPattern::TightLower, 0x5EED_0010u64),
        (LimitPattern::Pinned, 0x5EED_0011),
    ] {
        type Solve = fn(&Instance) -> fedzero::Result<Schedule>;
        for (family, solver) in [
            (Family::Convex, marin::solve as Solve),
            (Family::Affine, marco::solve as Solve),
            (Family::Concave, mardec::solve as Solve),
        ] {
            let gen = gen_for(family, limits, 12);
            let cfg = Config { cases: 40, seed, ..Default::default() };
            forall(&cfg, &gen, |case: &Case| check_matches_dp(case, solver));
        }
    }
}

/// Is `name`'s Table 2 optimality claim active on this scenario cell?
/// (`None` = the solver is a baseline: feasibility + never-below-oracle.)
/// Panics on a name it has never heard of, so registering a 13th solver
/// forces this differential to classify it rather than silently skip it.
fn optimality_claim(name: &str, family: Family, limits: LimitPattern) -> Option<bool> {
    match name {
        "auto" | "mc2mkp" | "bruteforce" => Some(true),
        "marin" => Some(matches!(family, Family::Convex | Family::Affine)),
        "marco" => Some(matches!(family, Family::Affine)),
        "mardec" => Some(matches!(family, Family::Concave | Family::Affine)),
        // MarDecUn additionally needs no effective upper limits after the
        // §5.2 transform: `UnlimitedWithLower` keeps U − L ≥ T − ΣL, and
        // `Pinned` makes the transformed workload zero.
        "mardecun" => Some(
            matches!(family, Family::Concave | Family::Affine)
                && matches!(
                    limits,
                    LimitPattern::Unlimited
                        | LimitPattern::UnlimitedWithLower
                        | LimitPattern::Pinned
                ),
        ),
        "uniform" | "random" | "proportional" | "greedy" | "olar" => None,
        other => panic!(
            "solver '{other}' is registered but unclassified — add it to \
             optimality_claim so the oracle differential covers it"
        ),
    }
}

#[test]
fn differential_vs_bruteforce_oracle_reaches_200_cases_per_solver() {
    const TARGET: usize = 200;
    // Derived from the registry, not hand-maintained: a newly registered
    // solver automatically joins the differential (and must be classified
    // by `optimality_claim`, which panics on unknown names).
    let all_solvers = SolverRegistry::with_defaults(0).names();
    let mut counts: BTreeMap<&str, usize> =
        all_solvers.iter().map(|&s| (s, 0usize)).collect();
    let combos: [(Family, LimitPattern, DupShape); 10] = [
        (Family::Convex, LimitPattern::Both, DupShape::Random),
        (Family::Affine, LimitPattern::Unlimited, DupShape::SingleClass),
        (Family::Concave, LimitPattern::UnlimitedWithLower, DupShape::Random),
        (Family::Tabulated, LimitPattern::Both, DupShape::Random),
        (Family::Affine, LimitPattern::UpperOnly, DupShape::Random),
        (Family::Concave, LimitPattern::Both, DupShape::AllUnique),
        (Family::Convex, LimitPattern::TightLower, DupShape::Random),
        (Family::Affine, LimitPattern::Pinned, DupShape::SingleClass),
        (
            Family::Concave,
            LimitPattern::UnlimitedWithLower,
            DupShape::SingleClass,
        ),
        (Family::Affine, LimitPattern::Both, DupShape::Random),
    ];
    let mut case_idx: u64 = 0;
    while counts.values().any(|&c| c < TARGET) {
        assert!(
            case_idx < 20_000,
            "differential failed to reach {TARGET} cases/solver: {counts:?}"
        );
        let (family, limits, dup) = combos[(case_idx as usize) % combos.len()];
        // Oracle-tiny instances: n <= 4, T <= 8 keeps exhaustive
        // enumeration trivial while still covering every scenario cell.
        let case = Case {
            seed: 0x0B5E ^ case_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            family,
            limits,
            dup,
            distinct: 2,
            max_dup: 2,
            t: 3 + (case_idx as usize % 6),
        };
        let inst = case.build();
        let oracle = bruteforce::solve(&inst)
            .unwrap_or_else(|e| panic!("oracle failed on {case:?}: {e}"));
        let opt = validate::checked_cost(&inst, &oracle)
            .unwrap_or_else(|e| panic!("oracle infeasible on {case:?}: {e}"));
        *counts.get_mut("bruteforce").unwrap() += 1;

        let registry = SolverRegistry::with_defaults(case.seed);
        let mut rng = Rng::new(case.seed ^ 0x0B5E);
        let tol = 1e-6 * opt.abs().max(1.0);
        for &name in &all_solvers {
            if name == "bruteforce" {
                continue; // it IS the oracle
            }
            let claim = optimality_claim(name, family, limits);
            if claim == Some(false) {
                continue; // outside the solver's scenario: no contract
            }
            let s = registry
                .solve_seeded(name, &inst, &mut rng)
                .unwrap_or_else(|e| panic!("{name} failed on {case:?}: {e}"));
            validate::check(&inst, &s)
                .unwrap_or_else(|e| panic!("{name} infeasible on {case:?}: {e}"));
            let c = validate::total_cost(&inst, &s);
            match claim {
                Some(true) => assert!(
                    (c - opt).abs() <= tol,
                    "{name} missed the oracle optimum on {case:?}: {c} vs {opt}"
                ),
                _ => assert!(
                    c >= opt - tol,
                    "{name} beat the oracle on {case:?}: {c} < {opt}"
                ),
            }
            *counts.get_mut(name).unwrap() += 1;
        }
        case_idx += 1;
    }
    for (name, c) in counts {
        assert!(c >= TARGET, "{name}: only {c} oracle cases");
    }
    println!("oracle differential complete after {case_idx} instances");
}

#[test]
fn lower_limit_transform_preserves_optimum() {
    let gen = gen_for(Family::Tabulated, LimitPattern::Both, 12);
    let cfg = Config { cases: 100, seed: 0x5EED_000B, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| {
        let inst = case.build();
        let tr = limits::remove_lower_limits(&inst);
        tr.instance.validate().map_err(|e| e.to_string())?;
        // Solve transformed, restore, compare to solving directly.
        let st = mc2mkp::solve(&tr.instance).map_err(|e| e.to_string())?;
        let restored = tr.restore(&st);
        validate::check(&inst, &restored).map_err(|e| e.to_string())?;
        let direct = mc2mkp::solve(&inst).map_err(|e| e.to_string())?;
        close(
            validate::total_cost(&inst, &restored),
            validate::total_cost(&inst, &direct),
            1e-6,
            "restored vs direct optimum",
        )
    });
}

#[test]
fn optimal_cost_monotone_in_t() {
    // With monotone costs, the optimal ΣC is non-decreasing in T.
    let gen = gen_for(Family::Convex, LimitPattern::UpperOnly, 18);
    let cfg = Config { cases: 60, seed: 0x5EED_000C, ..Default::default() };
    forall(&cfg, &gen, |case: &Case| {
        if case.t < 3 {
            return Ok(());
        }
        let inst_big = case.build();
        let mut inst_small = inst_big.clone();
        inst_small.tasks -= 1;
        inst_small.validate().map_err(|e| e.to_string())?;
        let cb = validate::total_cost(
            &inst_big,
            &mc2mkp::solve(&inst_big).map_err(|e| e.to_string())?,
        );
        let cs = validate::total_cost(
            &inst_small,
            &mc2mkp::solve(&inst_small).map_err(|e| e.to_string())?,
        );
        ensure(
            cb >= cs - 1e-9,
            format!("ΣC*({}) = {cb} < ΣC*({}) = {cs}", case.t, case.t - 1),
        )
    });
}
