//! Property-based certification of the paper's optimality theorems
//! (Theorems 1–5) against executable oracles:
//!
//! * every specialized algorithm matches the (MC)²MKP DP on its scenario;
//! * the DP matches brute-force enumeration on small instances;
//! * every produced schedule is feasible (eq. 1b–1c invariants);
//! * the §5.2 lower-limit transformation preserves optima.

use fedzero::sched::costs::CostFn;
use fedzero::sched::instance::Instance;
use fedzero::sched::{auto, bruteforce, limits, marco, mardec, mardecun, marin, mc2mkp, validate, SolverRegistry};
use fedzero::testkit::{close, ensure, forall, Config, Gen};
use fedzero::util::rng::Rng;

/// Which cost family a generated instance draws from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Family {
    Convex,
    Affine,
    Concave,
    Tabulated,
}

/// Random-instance generator with shrinking toward fewer resources /
/// smaller workloads.
#[derive(Clone, Debug)]
struct InstGen {
    family: Family,
    max_n: usize,
    max_t: usize,
    unlimited: bool,
    with_lower: bool,
}

/// The generated case: the instance plus its provenance (for debug output).
#[derive(Clone, Debug)]
struct Case {
    seed: u64,
    n: usize,
    t: usize,
    family: Family,
    unlimited: bool,
    with_lower: bool,
}

impl Case {
    fn build(&self) -> Instance {
        let mut rng = Rng::new(self.seed);
        let n = self.n;
        let t = self.t;
        let costs: Vec<CostFn> = (0..n)
            .map(|_| match self.family {
                Family::Convex => CostFn::Quadratic {
                    fixed: rng.range_f64(0.0, 2.0),
                    a: rng.range_f64(0.01, 1.0),
                    b: rng.range_f64(0.0, 3.0),
                },
                Family::Affine => CostFn::Affine {
                    fixed: rng.range_f64(0.0, 2.0),
                    per_task: rng.range_f64(0.1, 4.0),
                },
                Family::Concave => {
                    if rng.bool(0.5) {
                        CostFn::PowerLaw {
                            fixed: rng.range_f64(0.0, 1.0),
                            scale: rng.range_f64(0.3, 4.0),
                            exponent: rng.range_f64(0.2, 0.95),
                        }
                    } else {
                        CostFn::Logarithmic {
                            fixed: rng.range_f64(0.0, 1.0),
                            scale: rng.range_f64(0.3, 4.0),
                        }
                    }
                }
                Family::Tabulated => {
                    let mut values = vec![0.0];
                    let mut acc = 0.0;
                    for _ in 1..=t {
                        acc += rng.range_f64(0.0, 3.0);
                        // non-monotone wiggle allowed
                        values.push((acc + rng.normal() * 0.5).max(0.0));
                    }
                    CostFn::Tabulated { first: 0, values }
                }
            })
            .collect();

        let upper: Vec<usize> = if self.unlimited {
            vec![t; n]
        } else {
            let mut rng2 = Rng::new(self.seed ^ 0xFF);
            (0..n)
                .map(|_| 1 + rng2.index(t.max(1)))
                .collect()
        };
        let lower: Vec<usize> = if self.with_lower {
            let mut rng3 = Rng::new(self.seed ^ 0xAA);
            upper.iter().map(|&u| rng3.index((u / 2).max(1))).collect()
        } else {
            vec![0; n]
        };
        // Repair feasibility: shrink lower limits until ΣL <= T, then grow
        // upper limits until Σ min(U, T) >= T.
        let mut lower = lower;
        let mut i = 0;
        while lower.iter().sum::<usize>() > t {
            if lower[i % n] > 0 {
                lower[i % n] -= 1;
            }
            i += 1;
        }
        let mut upper = upper;
        while upper.iter().map(|&u| u.min(t)).sum::<usize>() < t {
            for u in upper.iter_mut() {
                *u += 1;
            }
        }
        Instance::new(t, lower, upper, costs).expect("generated valid")
    }
}

impl Gen<Case> for InstGen {
    fn generate(&self, rng: &mut Rng) -> Case {
        Case {
            seed: rng.next_u64(),
            n: 1 + rng.index(self.max_n),
            t: 2 + rng.index(self.max_t - 1),
            family: self.family,
            unlimited: self.unlimited,
            with_lower: self.with_lower,
        }
    }

    fn shrink(&self, c: &Case) -> Vec<Case> {
        let mut out = Vec::new();
        if c.n > 1 {
            out.push(Case { n: c.n - 1, ..c.clone() });
        }
        if c.t > 2 {
            out.push(Case { t: c.t / 2, ..c.clone() });
            out.push(Case { t: c.t - 1, ..c.clone() });
        }
        if c.with_lower {
            out.push(Case { with_lower: false, ..c.clone() });
        }
        out
    }
}

fn check_matches_dp(case: &Case, solver: fn(&Instance) -> fedzero::Result<Instance2Sched>) -> Result<(), String> {
    let inst = case.build();
    let s = solver(&inst).map_err(|e| format!("solver failed: {e}"))?;
    validate::check(&inst, &s).map_err(|e| format!("infeasible: {e}"))?;
    let c = validate::total_cost(&inst, &s);
    let dp = mc2mkp::solve(&inst).map_err(|e| format!("dp failed: {e}"))?;
    let cd = validate::total_cost(&inst, &dp);
    close(c, cd, 1e-6 * cd.abs().max(1.0), "cost vs DP")
}

type Instance2Sched = fedzero::sched::Schedule;

#[test]
fn dp_matches_bruteforce_on_small_arbitrary_instances() {
    let gen = InstGen {
        family: Family::Tabulated,
        max_n: 4,
        max_t: 14,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 150, seed: 0x5EED_0001, ..Default::default() };
    forall(&cfg, &gen, |case| {
        let inst = case.build();
        let dp = mc2mkp::solve(&inst).map_err(|e| e.to_string())?;
        let bf = bruteforce::solve(&inst).map_err(|e| e.to_string())?;
        validate::check(&inst, &dp).map_err(|e| e.to_string())?;
        close(
            validate::total_cost(&inst, &dp),
            validate::total_cost(&inst, &bf),
            1e-9,
            "dp vs brute force",
        )
    });
}

#[test]
fn marin_optimal_on_convex() {
    let gen = InstGen {
        family: Family::Convex,
        max_n: 6,
        max_t: 60,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 120, seed: 0x5EED_0002, ..Default::default() };
    forall(&cfg, &gen, |case| check_matches_dp(case, marin::solve));
}

#[test]
fn marco_optimal_on_affine() {
    let gen = InstGen {
        family: Family::Affine,
        max_n: 6,
        max_t: 60,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 120, seed: 0x5EED_0003, ..Default::default() };
    forall(&cfg, &gen, |case| check_matches_dp(case, marco::solve));
}

#[test]
fn mardecun_optimal_on_concave_unlimited() {
    let gen = InstGen {
        family: Family::Concave,
        max_n: 6,
        max_t: 50,
        unlimited: true,
        with_lower: true,
    };
    let cfg = Config { cases: 120, seed: 0x5EED_0004, ..Default::default() };
    forall(&cfg, &gen, |case| check_matches_dp(case, mardecun::solve));
}

#[test]
fn mardec_optimal_on_concave_limited() {
    let gen = InstGen {
        family: Family::Concave,
        max_n: 5,
        max_t: 40,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 120, seed: 0x5EED_0005, ..Default::default() };
    forall(&cfg, &gen, |case| check_matches_dp(case, mardec::solve));
}

#[test]
fn auto_always_feasible_and_optimal() {
    // auto must classify correctly and return an optimum for every family.
    for (family, seed) in [
        (Family::Convex, 0x5EED_0006u64),
        (Family::Affine, 0x5EED_0007),
        (Family::Concave, 0x5EED_0008),
        (Family::Tabulated, 0x5EED_0009),
    ] {
        let gen = InstGen {
            family,
            max_n: 5,
            max_t: 30,
            unlimited: false,
            with_lower: true,
        };
        let cfg = Config { cases: 60, seed, ..Default::default() };
        forall(&cfg, &gen, |case| check_matches_dp(case, auto::solve_auto));
    }
}

#[test]
fn baselines_always_feasible_never_below_optimal() {
    let gen = InstGen {
        family: Family::Tabulated,
        max_n: 5,
        max_t: 25,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 80, seed: 0x5EED_000A, ..Default::default() };
    forall(&cfg, &gen, |case| {
        let inst = case.build();
        let opt = validate::total_cost(
            &inst,
            &mc2mkp::solve(&inst).map_err(|e| e.to_string())?,
        );
        let mut rng = Rng::new(case.seed);
        let registry = SolverRegistry::with_defaults(case.seed);
        for policy in ["uniform", "random", "proportional", "greedy", "olar"] {
            let s = registry
                .solve_seeded(policy, &inst, &mut rng)
                .map_err(|e| format!("{policy}: {e}"))?;
            validate::check(&inst, &s).map_err(|e| format!("{policy}: {e}"))?;
            let c = validate::total_cost(&inst, &s);
            ensure(
                c >= opt - 1e-6 * opt.abs().max(1.0),
                format!("{policy} beat the optimum: {c} < {opt}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn lower_limit_transform_preserves_optimum() {
    let gen = InstGen {
        family: Family::Tabulated,
        max_n: 4,
        max_t: 16,
        unlimited: false,
        with_lower: true,
    };
    let cfg = Config { cases: 100, seed: 0x5EED_000B, ..Default::default() };
    forall(&cfg, &gen, |case| {
        let inst = case.build();
        let tr = limits::remove_lower_limits(&inst);
        tr.instance.validate().map_err(|e| e.to_string())?;
        // Solve transformed, restore, compare to solving directly.
        let st = mc2mkp::solve(&tr.instance).map_err(|e| e.to_string())?;
        let restored = tr.restore(&st);
        validate::check(&inst, &restored).map_err(|e| e.to_string())?;
        let direct = mc2mkp::solve(&inst).map_err(|e| e.to_string())?;
        close(
            validate::total_cost(&inst, &restored),
            validate::total_cost(&inst, &direct),
            1e-6,
            "restored vs direct optimum",
        )
    });
}

#[test]
fn optimal_cost_monotone_in_t() {
    // With monotone costs, the optimal ΣC is non-decreasing in T.
    let gen = InstGen {
        family: Family::Convex,
        max_n: 4,
        max_t: 20,
        unlimited: false,
        with_lower: false,
    };
    let cfg = Config { cases: 60, seed: 0x5EED_000C, ..Default::default() };
    forall(&cfg, &gen, |case| {
        if case.t < 3 {
            return Ok(());
        }
        let inst_big = case.build();
        let mut inst_small = inst_big.clone();
        inst_small.tasks -= 1;
        inst_small.validate().map_err(|e| e.to_string())?;
        let cb = validate::total_cost(
            &inst_big,
            &mc2mkp::solve(&inst_big).map_err(|e| e.to_string())?,
        );
        let cs = validate::total_cost(
            &inst_small,
            &mc2mkp::solve(&inst_small).map_err(|e| e.to_string())?,
        );
        ensure(cb >= cs - 1e-9, format!("ΣC*({}) = {cb} < ΣC*({}) = {cs}", case.t, case.t - 1))
    });
}
