//! Offline stub of the PJRT/XLA Rust bindings.
//!
//! The build environment has no network access and no PJRT plugin, so this
//! crate vendors the *API surface* fedzero's `runtime` layer compiles
//! against: host-side [`Literal`] tensors (fully functional), HLO artifact
//! loading (functional: reads and retains the text), and PJRT
//! client/executable types whose `execute` path fails with a descriptive
//! [`Error`] instead of running XLA.
//!
//! Swapping in the real bindings is a Cargo.toml change only; nothing in
//! fedzero references stub-only items.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` — an opaque message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires a PJRT plugin, but fedzero was built against the \
         vendored offline `xla` stub (rust/vendor/xla). Link the real xla \
         crate to execute compiled HLO."
    ))
}

/// Element types a [`Literal`] can hold. Sealed: only `f32`/`i32` are used
/// by fedzero's calling convention.
pub trait NativeType: Copy + private::Sealed {
    #[doc(hidden)]
    fn from_elem(e: &Elem) -> Option<Self>;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn unwrap(s: &Storage) -> Option<&[Self]>;
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// One scalar element (used by `get_first_element`).
#[derive(Debug, Clone, Copy)]
pub enum Elem {
    F32(f32),
    I32(i32),
}

/// Backing storage of a literal.
#[derive(Debug, Clone)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    /// Tuple literals (what executables return).
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn from_elem(e: &Elem) -> Option<f32> {
        match e {
            Elem::F32(v) => Some(*v),
            Elem::I32(_) => None,
        }
    }
    fn wrap(v: Vec<f32>) -> Storage {
        Storage::F32(v)
    }
    fn unwrap(s: &Storage) -> Option<&[f32]> {
        match s {
            Storage::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn from_elem(e: &Elem) -> Option<i32> {
        match e {
            Elem::I32(v) => Some(*v),
            Elem::F32(_) => None,
        }
    }
    fn wrap(v: Vec<i32>) -> Storage {
        Storage::I32(v)
    }
    fn unwrap(s: &Storage) -> Option<&[i32]> {
        match s {
            Storage::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side tensor, matching the subset of `xla::Literal` fedzero uses.
#[derive(Debug, Clone)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            storage: T::wrap(data.to_vec()),
        }
    }

    fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(_) => 0,
        }
    }

    /// Reinterpret under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error("cannot reshape a tuple literal".into()));
        }
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape to {dims:?} ({want} elements) from {have} elements"
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Array shape (error for tuple literals).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error("tuple literal has no array shape".into()));
        }
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Decompose a 1-tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        let mut v = self.to_tuple()?;
        if v.len() != 1 {
            return Err(Error(format!("expected 1-tuple, got {}", v.len())));
        }
        Ok(v.pop().unwrap())
    }

    /// First scalar of the literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let elem = match &self.storage {
            Storage::F32(v) => v.first().copied().map(Elem::F32),
            Storage::I32(v) => v.first().copied().map(Elem::I32),
            Storage::Tuple(_) => None,
        }
        .ok_or_else(|| Error("empty or tuple literal".into()))?;
        T::from_elem(&elem).ok_or_else(|| Error("element type mismatch".into()))
    }

    /// Copy out the flat host data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal type mismatch in to_vec".into()))
    }
}

/// Parsed HLO module artifact. The stub validates the file exists and keeps
/// its text; it cannot verify or execute the program.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact from disk.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("reading {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }

    /// Raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client handle. Construction fails in the stub: there is no plugin
/// to back it, and failing here gives callers one clean early error instead
/// of a surprise at execute time.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// CPU client — unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu()"))
    }

    /// Compile a computation — unreachable while `cpu()` errors, kept for
    /// API parity.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile()"))
    }
}

/// A device buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Synchronously copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync()"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (one replica, one partition).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute()"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(r.get_first_element::<f32>().unwrap(), 1.0);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let l = Literal::vec1(&[7i32, 8]);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn client_unavailable_is_descriptive() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn hlo_from_missing_file_errors() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo").is_err());
    }
}
