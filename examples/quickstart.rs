//! Quickstart: the paper's worked example (§3.1, Figs. 1–2) plus a
//! synthetic fleet, solved with every scheduler.
//!
//! Run with: `cargo run --release --example quickstart`

use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::sched::costs::CostFn;
use fedzero::sched::fleet::FleetInstance;
use fedzero::sched::instance::Instance;
use fedzero::sched::{auto, validate, SolverRegistry};
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_energy, Table};

fn main() -> fedzero::Result<()> {
    // ---- Part 1: the paper's own example --------------------------------
    println!("Minimal Cost FL Schedule — paper §3.1 worked example\n");
    for (tasks, expect) in [(5usize, "{2, 3, 0} (Fig. 1)"), (8, "{1, 2, 5} (Fig. 2)")] {
        let inst = Instance::paper_example(tasks);
        let sched = auto::solve_auto(&inst)?;
        let cost = validate::checked_cost(&inst, &sched)?;
        println!("T = {tasks}: X* = {sched}   ΣC = {cost}   — paper: {expect}");
    }
    println!();

    // ---- Part 2: a synthetic heterogeneous fleet ------------------------
    let mut rng = Rng::new(42);
    let fleet = Fleet::sample(8, BehaviorMix::Homogeneous(Behavior::Convex), &mut rng);
    let tasks = 200.min(fleet.capacity());
    let inst = fleet.instance(tasks, 1)?;
    println!("Synthetic fleet: n = {}, T = {tasks}, lower limit 1/device\n", fleet.len());

    let registry = SolverRegistry::with_defaults(42);
    let policies = [
        "auto", "mc2mkp", "marin", "uniform", "random", "proportional",
        "greedy", "olar",
    ];
    let mut table = Table::new(
        "scheduler comparison (convex energy, lower is better)",
        &["policy", "schedule", "total energy", "vs optimal"],
    );
    let optimal =
        validate::total_cost(&inst, &registry.solve_seeded("mc2mkp", &inst, &mut rng)?);
    for p in policies {
        let sched = registry.solve_seeded(p, &inst, &mut rng)?;
        validate::check(&inst, &sched)?;
        let cost = validate::total_cost(&inst, &sched);
        table.rows_str(vec![
            p.to_string(),
            sched.to_string(),
            fmt_energy(cost),
            format!("{:+.1}%", (cost / optimal - 1.0) * 100.0),
        ]);
    }
    table.print();
    println!("\nThe paper's optimal algorithms (auto/mc2mkp/marin) coincide at the");
    println!("minimum; baselines pay an energy premium.");

    // ---- Part 3: fleet-scale scheduling via device classes --------------
    // Real fleets repeat hardware archetypes; building a FleetInstance
    // deduplicates interchangeable devices so solvers run per *class*.
    let fleet_inst = FleetInstance::builder()
        .tasks(5_000)
        .device_class(CostFn::Affine { fixed: 0.2, per_task: 1.0 }, 0, 8, 400)
        .device_class(CostFn::Affine { fixed: 0.1, per_task: 2.5 }, 0, 8, 400)
        .device_class(CostFn::Affine { fixed: 0.5, per_task: 4.0 }, 0, 16, 200)
        .build()?;
    let assignment = registry.solve_fleet("auto", &fleet_inst)?;
    assignment.check(&fleet_inst)?;
    println!(
        "\nFleet-scale: {} devices in {} classes, T = {} → total energy {} \
         (expand() recovers all {} per-device loads on demand)",
        fleet_inst.n_devices(),
        fleet_inst.n_classes(),
        fleet_inst.tasks,
        fmt_energy(assignment.total_cost(&fleet_inst)),
        assignment.expand(&fleet_inst).len(),
    );
    Ok(())
}
