//! Fleet inspection: archetype sampling, cost-regime classification, and
//! limit derivation (battery + data caps → `U_i`).
//!
//! Run with: `cargo run --release --example device_fleet`

use fedzero::energy::profiles::{BehaviorMix, Fleet, ARCHETYPES};
use fedzero::sched::auto;
use fedzero::sched::costs::classify;
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_energy, Table};

fn main() -> fedzero::Result<()> {
    println!("Archetype catalog:\n");
    let mut cat = Table::new(
        "archetypes",
        &["name", "busy W", "s/batch", "data batches", "battery"],
    );
    for a in &ARCHETYPES {
        cat.rows_str(vec![
            a.name.to_string(),
            format!("{:.1}–{:.1}", a.busy_w.0, a.busy_w.1),
            format!("{:.2}–{:.2}", a.batch_latency_s.0, a.batch_latency_s.1),
            format!("{}–{}", a.data_batches.0, a.data_batches.1),
            match a.battery_wh {
                Some((lo, hi)) => format!("{lo:.0}–{hi:.0} Wh"),
                None => "mains".into(),
            },
        ]);
    }
    cat.print();

    let mut rng = Rng::new(7);
    let fleet = Fleet::sample(12, BehaviorMix::Mixed, &mut rng);
    let mut table = Table::new(
        "sampled fleet (mixed behaviours)",
        &["id", "archetype", "behavior", "regime over [0,U]", "U_i", "E(U_i)"],
    );
    for d in &fleet.devices {
        let u = d.upper_limit();
        let regime = classify(&d.cost_fn(), 0, u.max(2));
        table.rows_str(vec![
            d.id.to_string(),
            d.archetype.to_string(),
            format!("{:?}", d.power.behavior),
            format!("{regime:?}"),
            u.to_string(),
            fmt_energy(d.power.energy_j(u)),
        ]);
    }
    table.print();

    let tasks = fleet.capacity() / 3;
    let inst = fleet.instance(tasks, 0)?;
    let scenario = auto::classify_instance(&inst);
    println!(
        "\ninstance: T = {tasks}, combined regime {:?}, upper limits bind: {}",
        scenario.regime, scenario.has_upper_limits
    );
    println!("→ Table 2 dispatch picks: {}", auto::best_algorithm(&scenario));
    Ok(())
}
