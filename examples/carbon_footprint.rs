//! Carbon & monetary cost minimization (paper §6 remark I): the same
//! schedulers minimize g CO₂e or EUR instead of joules by weighting each
//! device's energy cost with its grid's carbon intensity / electricity
//! price.
//!
//! The headline effect (after Qiu et al. [12]): energy-optimal and
//! carbon-optimal schedules *differ* — a slightly less energy-efficient
//! device on a clean grid can be the carbon-optimal choice.
//!
//! Run with: `cargo run --release --example carbon_footprint`

use fedzero::energy::carbon;
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::sched::instance::Instance;
use fedzero::sched::{validate, SolverRegistry};
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_energy, Table};

fn main() -> fedzero::Result<()> {
    let mut rng = Rng::new(11);
    let fleet = Fleet::sample(10, BehaviorMix::Homogeneous(Behavior::Linear), &mut rng);
    let tasks = (fleet.capacity() / 3).max(10);

    // Three cost views over the same fleet.
    let energy_inst = fleet.instance(tasks, 0)?;
    let carbon_costs = fleet
        .devices
        .iter()
        .map(|d| carbon::carbon_cost(d.cost_fn(), d.region))
        .collect::<fedzero::Result<Vec<_>>>()?;
    let money_costs = fleet
        .devices
        .iter()
        .map(|d| carbon::monetary_cost(d.cost_fn(), d.region))
        .collect::<fedzero::Result<Vec<_>>>()?;
    let carbon_inst = Instance::new(
        energy_inst.tasks,
        energy_inst.lower.clone(),
        energy_inst.upper.clone(),
        carbon_costs,
    )?;
    let money_inst = Instance::new(
        energy_inst.tasks,
        energy_inst.lower.clone(),
        energy_inst.upper.clone(),
        money_costs,
    )?;

    let mut rng2 = Rng::new(0);
    let registry = SolverRegistry::with_defaults(0);
    let sched_energy = registry.solve_seeded("auto", &energy_inst, &mut rng2)?;
    let sched_carbon = registry.solve_seeded("auto", &carbon_inst, &mut rng2)?;
    let sched_money = registry.solve_seeded("auto", &money_inst, &mut rng2)?;

    let mut table = Table::new(
        &format!("workload by optimization target (T = {tasks})"),
        &["device", "region", "gCO2/kWh", "x_i (energy)", "x_i (carbon)", "x_i (money)"],
    );
    for (i, d) in fleet.devices.iter().enumerate() {
        let (co2, _) = carbon::region(d.region)?;
        table.rows_str(vec![
            format!("{} ({})", d.id, d.archetype),
            d.region.to_string(),
            format!("{co2:.0}"),
            sched_energy.get(i).to_string(),
            sched_carbon.get(i).to_string(),
            sched_money.get(i).to_string(),
        ]);
    }
    table.print();

    // Cross-evaluate each schedule under each metric.
    let mut cross = Table::new(
        "cross-evaluation (rows: schedule optimized for; cols: measured as)",
        &["schedule", "energy", "carbon gCO2e", "cost EUR"],
    );
    for (name, s) in [
        ("energy-optimal", &sched_energy),
        ("carbon-optimal", &sched_carbon),
        ("money-optimal", &sched_money),
    ] {
        cross.rows_str(vec![
            name.to_string(),
            fmt_energy(validate::total_cost(&energy_inst, s)),
            format!("{:.3}", validate::total_cost(&carbon_inst, s)),
            format!("{:.5}", validate::total_cost(&money_inst, s)),
        ]);
    }
    cross.print();

    let e_carbon = validate::total_cost(&carbon_inst, &sched_energy);
    let c_carbon = validate::total_cost(&carbon_inst, &sched_carbon);
    println!(
        "\ncarbon saved by carbon-aware scheduling vs energy-only: {:.1}%",
        (1.0 - c_carbon / e_carbon) * 100.0
    );
    Ok(())
}
