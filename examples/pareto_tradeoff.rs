//! Energy/time Pareto front (cf. Khaleghzadeh et al. [28], which the paper
//! cites as the bi-objective alternative): energy-minimal schedules subject
//! to round-deadline (makespan) constraints, via ε-constraint solves of the
//! Minimal Cost FL Schedule problem on the class-deduplicated fleet.
//!
//! Run with: `cargo run --release --example pareto_tradeoff`

use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::sched::pareto::{BiFleet, TimeModel, DEFAULT_UPLOAD_S};
use fedzero::sched::SolverRegistry;
use fedzero::util::rng::Rng;
use fedzero::util::table::{fmt_duration, fmt_energy, Table};

fn main() -> fedzero::Result<()> {
    let mut rng = Rng::new(23);
    let fleet = Fleet::sample(8, BehaviorMix::Homogeneous(Behavior::Linear), &mut rng);
    let tasks = (fleet.capacity() / 4).max(8);

    let energy = fleet.instance(tasks, 0)?;
    let times: Vec<TimeModel> = fleet
        .devices
        .iter()
        .map(|d| TimeModel::affine(d.power.batch_latency_s, DEFAULT_UPLOAD_S))
        .collect();
    let bi = BiFleet::from_flat(&energy, &times)?;

    let registry = SolverRegistry::with_defaults(23);
    let front = bi.pareto_front(&registry, "mc2mkp")?;
    let mut table = Table::new(
        &format!(
            "energy/makespan Pareto front — n={}, T={tasks} ({} points, sampled)",
            fleet.len(),
            front.len()
        ),
        &["point", "deadline (makespan)", "energy", "solver", "schedule"],
    );
    let step = (front.len() / 14).max(1);
    for (i, p) in front.iter().enumerate() {
        if i % step != 0 && i != front.len() - 1 {
            continue;
        }
        table.rows_str(vec![
            i.to_string(),
            fmt_duration(p.makespan),
            fmt_energy(p.energy),
            p.solver.to_string(),
            p.schedule.to_string(),
        ]);
    }
    table.print();

    if front.len() >= 2 {
        let fast = &front[0];
        let frugal = front.last().unwrap();
        println!(
            "\ntightest deadline costs {:.1}% more energy than the unconstrained optimum;",
            (fast.energy / frugal.energy - 1.0) * 100.0
        );
        println!(
            "relaxing the deadline {:.1}× buys that energy back ({} → {}).",
            frugal.makespan / fast.makespan,
            fmt_energy(fast.energy),
            fmt_energy(frugal.energy)
        );
    }
    Ok(())
}
