//! EX-A: total-energy comparison of the paper's optimal schedulers vs
//! baselines across the three marginal-cost regimes and fleet sizes —
//! the evaluation the paper's §6 calls for.
//!
//! Run with: `cargo run --release --example energy_study`

use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::{BehaviorMix, Fleet};
use fedzero::sched::{validate, SolverRegistry};
use fedzero::util::csv::CsvWriter;
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::table::Table;

const POLICIES: [&str; 6] =
    ["auto", "uniform", "random", "proportional", "greedy", "olar"];

fn main() -> fedzero::Result<()> {
    let regimes = [
        ("increasing", BehaviorMix::Homogeneous(Behavior::Convex)),
        ("constant", BehaviorMix::Homogeneous(Behavior::Linear)),
        ("decreasing", BehaviorMix::Homogeneous(Behavior::Concave)),
        ("arbitrary", BehaviorMix::Mixed),
    ];
    let fleet_sizes = [10usize, 50, 200];
    let trials = 10u64;
    let registry = SolverRegistry::with_defaults(99);

    let mut csv = CsvWriter::new(&[
        "regime", "n", "policy", "mean_overhead_pct", "max_overhead_pct",
    ]);

    for (regime_name, mix) in regimes {
        let mut table = Table::new(
            &format!("energy overhead vs optimal — {regime_name} marginal costs"),
            &["n", "policy", "mean +%", "max +%"],
        );
        for &n in &fleet_sizes {
            // overheads[policy][trial]
            let mut overheads: Vec<Vec<f64>> = vec![Vec::new(); POLICIES.len()];
            for trial in 0..trials {
                let mut rng = Rng::new(1000 * trial + n as u64);
                let fleet = Fleet::sample(n, mix, &mut rng);
                let tasks = (fleet.capacity() / 3).max(n);
                let inst = fleet.instance(tasks, 0)?;
                let opt = validate::total_cost(
                    &inst,
                    &registry.solve_seeded("mc2mkp", &inst, &mut rng)?,
                );
                for (pi, &p) in POLICIES.iter().enumerate() {
                    let sched = registry.solve_seeded(p, &inst, &mut rng)?;
                    validate::check(&inst, &sched)?;
                    let cost = validate::total_cost(&inst, &sched);
                    overheads[pi].push((cost / opt - 1.0) * 100.0);
                }
            }
            for (pi, &p) in POLICIES.iter().enumerate() {
                let mean = stats::mean(&overheads[pi]);
                let (_, max) = stats::min_max(&overheads[pi]);
                table.rows_str(vec![
                    n.to_string(),
                    p.to_string(),
                    format!("{mean:+.2}"),
                    format!("{max:+.2}"),
                ]);
                csv.rowd(&[&regime_name, &n, &p, &mean, &max]);
            }
        }
        table.print();
        println!();
    }

    let out = std::path::Path::new("target/energy_study.csv");
    csv.save(out)?;
    println!("raw rows written to {}", out.display());
    println!("Reading the tables: the paper's optimal schedulers (auto) sit at +0%;");
    println!("baselines pay regime-dependent premiums — largest under decreasing");
    println!("marginal costs, where spreading work is maximally wasteful.");
    Ok(())
}
