//! EX-B — the end-to-end driver: full federated training through all three
//! layers (Rust coordinator → PJRT → AOT-lowered JAX/Pallas steps),
//! comparing scheduler policies on loss, energy, and simulated round time.
//!
//! Requires artifacts: `make artifacts` first.
//! Run with: `cargo run --release --example federated_training -- [model] [rounds]`
//! (defaults: mlp 200; `transformer 60` exercises the LM).
//!
//! Results are recorded in EXPERIMENTS.md §EX-B.

use fedzero::config::{Policy, TrainConfig};
use fedzero::energy::power::Behavior;
use fedzero::energy::profiles::BehaviorMix;
use fedzero::fl::Server;
use fedzero::util::csv::CsvWriter;
use fedzero::util::table::{fmt_energy, Table};

fn main() -> fedzero::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(|s| s.as_str()).unwrap_or("mlp").to_string();
    let rounds: usize = args
        .get(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(if model == "mlp" { 200 } else { 60 });

    let policies = [Policy::Auto, Policy::Uniform, Policy::Random, Policy::Olar];
    // Convex energy: sustained load costs devices superlinearly — the
    // regime where workload placement matters most per joule.
    let mix = BehaviorMix::Homogeneous(Behavior::Convex);

    let base = TrainConfig {
        rounds,
        devices: if model == "mlp" { 40 } else { 12 },
        tasks_per_round: if model == "mlp" { 256 } else { 48 },
        model: model.clone(),
        seed: 17,
        dirichlet_alpha: 0.5,
        min_tasks: 0,
        participation: 0.5,
        ..TrainConfig::default()
    };

    println!(
        "federated training: model={model}, {} devices, T={} mini-batches/round, {rounds} rounds\n",
        base.devices, base.tasks_per_round
    );

    let mut summary = Table::new(
        "end-to-end comparison (same fleet & data seed per policy)",
        &["policy", "final loss", "total energy", "energy vs auto", "wall s"],
    );
    let mut csv = CsvWriter::new(&[
        "policy", "round", "loss", "energy_j", "sched_time_s", "train_time_s",
    ]);

    let mut auto_energy = None;
    for policy in policies {
        let mut cfg = base.clone();
        cfg.policy = policy;
        let wall = std::time::Instant::now();
        let mut server = Server::new(cfg, mix)?;
        server.run()?;
        let wall_s = wall.elapsed().as_secs_f64();

        for row in server.log().rows() {
            csv.rowd(&[
                &row.policy,
                &row.round,
                &row.loss,
                &row.energy_j,
                &row.sched_time_s,
                &row.train_time_s,
            ]);
        }
        let total = server.log().total_energy();
        if policy == Policy::Auto {
            auto_energy = Some(total);
        }
        let vs = auto_energy
            .map(|a| format!("{:+.1}%", (total / a - 1.0) * 100.0))
            .unwrap_or_else(|| "—".into());
        summary.rows_str(vec![
            policy.to_string(),
            format!("{:.4}", server.log().final_loss().unwrap_or(f64::NAN)),
            fmt_energy(total),
            vs,
            format!("{wall_s:.1}"),
        ]);

        // Loss curve sketch every ~10% of rounds.
        println!("policy {policy}: loss curve");
        let step = (rounds / 10).max(1);
        for row in server.log().rows().iter().step_by(step) {
            println!(
                "  round {:>4}  loss {:.4}  round energy {}",
                row.round,
                row.loss,
                fmt_energy(row.energy_j)
            );
        }
        println!(
            "  max single-device energy share: {:.1}%\n",
            server.ledger().max_device_share() * 100.0
        );
    }

    summary.print();
    let out = std::path::Path::new("target/federated_training.csv");
    csv.save(out)?;
    println!("\nper-round log written to {}", out.display());
    Ok(())
}
